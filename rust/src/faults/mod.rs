//! Seeded, deterministic fault injection for chaos testing the serving
//! stack.
//!
//! A [`FaultPlan`] names *sites* (string labels compiled into the hot
//! paths: `decoder.extend`, `kernel.gemm`, `arena.alloc`,
//! `pjrt.session`, and the pool-level sites `worker.tick`,
//! `worker.wedge`, `queue.reclaim`) and attaches rules that fire a
//! fault — a panic, an
//! injected `Err`, or a slow-down sleep — at some of the hits on that
//! site. Decisions are a pure function of `(seed, site, rule, hit
//! counter)`: re-running the same workload under the same plan injects
//! the same faults at the same points, which is what lets the chaos
//! property tests compare a faulted run against a fault-free oracle.
//!
//! The module is std-only and **inert by default**: every instrumented
//! site costs one relaxed atomic load until a plan is installed, so the
//! production hot paths pay nothing. Plans are armed explicitly — by
//! tests via [`install`], or by `rxnspec serve` from the
//! `RXNSPEC_FAULTS=<seed>:<spec>` environment variable (see
//! [`plan_from_env`] for the grammar). Merely *setting* the variable
//! never affects library users that don't opt in.
//!
//! The plan is process-global (the sites are free functions on hot
//! paths); tests that install plans serialize on their own lock and
//! [`disarm`] when done.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use anyhow::{bail, Result};

/// What an armed rule does at a matched hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the site (models a decoder/kernel bug or an
    /// allocation failure — the supervision layer must contain it).
    Panic,
    /// Sleep this many milliseconds, then proceed (models a stall; the
    /// deadline layer must shed around it).
    Slow(u64),
    /// Return an `Err` from the site (sites without a `Result` path
    /// escalate this to a panic).
    Err,
}

/// When a rule fires: on a pseudo-random fraction of hits, or on exactly
/// one deterministic hit (1-based) — the latter is what targeted unit
/// tests use.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    Prob(f64),
    Nth(u64),
}

#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    pub site: String,
    pub kind: FaultKind,
    pub trigger: Trigger,
}

/// A seeded set of rules. `Default` is an empty (fires-nothing) plan.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rules: Vec::new(),
        }
    }

    /// Builder-style rule append.
    pub fn with(mut self, site: &str, kind: FaultKind, trigger: Trigger) -> FaultPlan {
        self.rules.push(FaultRule {
            site: site.to_string(),
            kind,
            trigger,
        });
        self
    }
}

/// Every fault site the production tree may fire, sorted. A site
/// literal passed to `fire`/`fire_infallible`/`fires` outside this
/// module (and any site named in a CI fault schedule) must appear here —
/// enforced by the `fault-site` rule in [`crate::lint`], so a typoed
/// site can never silently never-fire.
pub const SITES: &[&str] = &[
    "arena.alloc",
    "decoder.extend",
    "kernel.gemm",
    "pjrt.session",
    "queue.reclaim",
    "worker.tick",
    "worker.wedge",
];

struct PlanState {
    plan: Option<FaultPlan>,
    /// Per-site hit counters since the plan was installed.
    hits: HashMap<String, u64>,
}

/// Fast inert-path gate: `fire()` is one relaxed load when no plan is
/// armed.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Total faults fired since process start (across installs).
static INJECTED: AtomicU64 = AtomicU64::new(0);

fn state() -> &'static Mutex<PlanState> {
    static S: OnceLock<Mutex<PlanState>> = OnceLock::new();
    S.get_or_init(|| {
        Mutex::new(PlanState {
            plan: None,
            hits: HashMap::new(),
        })
    })
}

fn lock_state() -> std::sync::MutexGuard<'static, PlanState> {
    // A panic *is* this module's product; never let one poison us.
    crate::coordinator::lock_ok(state())
}

/// Arm a plan (replacing any previous one) and reset all hit counters.
pub fn install(plan: FaultPlan) {
    let mut g = lock_state();
    g.hits.clear();
    let armed = !plan.rules.is_empty();
    g.plan = Some(plan);
    ACTIVE.store(armed, Ordering::SeqCst);
}

/// Disarm: sites go back to the one-atomic-load inert path.
pub fn disarm() {
    let mut g = lock_state();
    g.plan = None;
    g.hits.clear();
    ACTIVE.store(false, Ordering::SeqCst);
}

/// Total faults fired since process start.
pub fn injected() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Hits recorded at `site` under the current plan (0 when disarmed).
pub fn hits(site: &str) -> u64 {
    lock_state().hits.get(site).copied().unwrap_or(0)
}

/// Parse `RXNSPEC_FAULTS=<seed>:<spec>` where `<spec>` is a
/// comma-separated list of `site=kind@prob` or `site=kind#nth` rules and
/// `kind` is `panic`, `err`, or `slow<ms>`:
///
/// ```text
/// RXNSPEC_FAULTS="7:decoder.extend=panic@0.02,decoder.extend=slow5@0.05,arena.alloc=panic#3"
/// ```
///
/// Returns `None` when the variable is unset; `Err` on a malformed spec
/// (callers surface it rather than silently serving without chaos).
pub fn plan_from_env() -> Option<Result<FaultPlan>> {
    let raw = crate::knobs::FAULTS.raw()?;
    if raw.trim().is_empty() {
        return None;
    }
    Some(parse_spec(&raw))
}

/// Parse the `RXNSPEC_FAULTS` grammar from a string (see
/// [`plan_from_env`]).
pub fn parse_spec(raw: &str) -> Result<FaultPlan> {
    let Some((seed_s, rules_s)) = raw.split_once(':') else {
        bail!("fault spec {raw:?}: expected <seed>:<rules>");
    };
    let seed: u64 = seed_s
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("fault spec: bad seed {seed_s:?}"))?;
    let mut plan = FaultPlan::new(seed);
    for rule_s in rules_s.split(',') {
        let rule_s = rule_s.trim();
        if rule_s.is_empty() {
            continue;
        }
        let Some((site, action)) = rule_s.split_once('=') else {
            bail!("fault rule {rule_s:?}: expected site=kind@prob or site=kind#nth");
        };
        let (kind_s, trigger) = if let Some((k, p)) = action.split_once('@') {
            let prob: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("fault rule {rule_s:?}: bad probability {p:?}"))?;
            if !(0.0..=1.0).contains(&prob) {
                bail!("fault rule {rule_s:?}: probability out of [0,1]");
            }
            (k, Trigger::Prob(prob))
        } else if let Some((k, n)) = action.split_once('#') {
            let nth: u64 = n
                .parse()
                .map_err(|_| anyhow::anyhow!("fault rule {rule_s:?}: bad hit index {n:?}"))?;
            if nth == 0 {
                bail!("fault rule {rule_s:?}: hit indices are 1-based");
            }
            (k, Trigger::Nth(nth))
        } else {
            bail!("fault rule {rule_s:?}: missing @prob or #nth");
        };
        let kind = if kind_s == "panic" {
            FaultKind::Panic
        } else if kind_s == "err" {
            FaultKind::Err
        } else if let Some(ms) = kind_s.strip_prefix("slow") {
            FaultKind::Slow(
                ms.parse()
                    .map_err(|_| anyhow::anyhow!("fault rule {rule_s:?}: bad slow ms {ms:?}"))?,
            )
        } else {
            bail!("fault rule {rule_s:?}: unknown kind {kind_s:?} (panic|err|slow<ms>)");
        };
        plan.rules.push(FaultRule {
            site: site.trim().to_string(),
            kind,
            trigger,
        });
    }
    Ok(plan)
}

/// splitmix64-style mix of `(seed, site, rule index, hit number)` to a
/// uniform value in `[0, 1)` — the deterministic coin every `Prob` rule
/// flips.
fn unit_hash(seed: u64, site: &str, rule: u64, n: u64) -> f64 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for b in site.bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h ^= rule.wrapping_mul(0xD1B5_4A32_D192_ED03);
    h ^= n.wrapping_mul(0x2545_F491_4F6C_DD1D);
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^= h >> 31;
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Count a hit at `site` and return the fault to apply, if any. First
/// matching rule wins.
fn decide(site: &str) -> Option<FaultKind> {
    let mut g = lock_state();
    let (seed, matching): (u64, Vec<(u64, FaultKind, Trigger)>) = match &g.plan {
        None => return None,
        Some(p) => (
            p.seed,
            p.rules
                .iter()
                .enumerate()
                .filter(|(_, r)| r.site == site)
                .map(|(i, r)| (i as u64, r.kind, r.trigger))
                .collect(),
        ),
    };
    if matching.is_empty() {
        return None;
    }
    let n = {
        let c = g.hits.entry(site.to_string()).or_insert(0);
        *c += 1;
        *c
    };
    drop(g);
    for (idx, kind, trigger) in matching {
        let fires = match trigger {
            Trigger::Prob(p) => unit_hash(seed, site, idx, n) < p,
            Trigger::Nth(k) => n == k,
        };
        if fires {
            INJECTED.fetch_add(1, Ordering::Relaxed);
            return Some(kind);
        }
    }
    None
}

/// Instrumentation hook for sites with a `Result` path. Inert (one
/// relaxed atomic load) unless a plan is armed.
#[inline]
pub fn fire(site: &str) -> Result<()> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(());
    }
    match decide(site) {
        None => Ok(()),
        Some(FaultKind::Slow(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::Panic) => panic!("injected fault: panic at {site}"),
        Some(FaultKind::Err) => bail!("injected fault: err at {site}"),
    }
}

/// Instrumentation hook for sites without a `Result` path (kernels,
/// allocation): `Err` rules escalate to panics here.
#[inline]
pub fn fire_infallible(site: &str) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    if fire(site).is_err() {
        panic!("injected fault: err at {site} (infallible site)");
    }
}

/// Instrumentation hook for *behavioural* sites: counts a hit and
/// reports whether a rule fired, without applying the fault kind. Used
/// where the "fault" is a mode change rather than a panic/stall — e.g.
/// `worker.wedge` freezes the worker loop so the pool supervisor must
/// reclaim its in-flight requests. Inert (one relaxed atomic load)
/// unless a plan is armed.
#[inline]
pub fn fires(site: &str) -> bool {
    if !ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    decide(site).is_some()
}

/// Helpers for tests that arm the process-global fault plan — shared by
/// this module's tests and the supervision tests in `worker.rs`. (The
/// out-of-crate chaos suite runs in its own process and carries its own
/// lock.)
#[cfg(test)]
pub mod testing {
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// Plan installation is process-global; every test that arms a plan
    /// serializes on this lock and disarms on exit.
    pub fn lock() -> MutexGuard<'static, ()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        crate::coordinator::lock_ok(L.get_or_init(|| Mutex::new(())))
    }

    /// Drop guard: disarms the global plan even if the test panics.
    pub struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            super::disarm();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::testing::{lock as test_lock, Disarm};

    #[test]
    fn inert_without_plan() {
        let _g = test_lock();
        let _d = Disarm;
        disarm();
        for _ in 0..100 {
            fire("decoder.extend").unwrap();
            fire_infallible("kernel.gemm");
        }
        assert_eq!(hits("decoder.extend"), 0, "disarmed sites must not count");
    }

    #[test]
    fn nth_trigger_fires_exactly_once() {
        let _g = test_lock();
        let _d = Disarm;
        install(FaultPlan::new(1).with("s", FaultKind::Err, Trigger::Nth(3)));
        let outcomes: Vec<bool> = (0..6).map(|_| fire("s").is_err()).collect();
        assert_eq!(outcomes, vec![false, false, true, false, false, false]);
        assert_eq!(hits("s"), 6);
    }

    #[test]
    fn prob_trigger_is_deterministic_and_roughly_calibrated() {
        let _g = test_lock();
        let _d = Disarm;
        let run = || -> Vec<bool> {
            install(FaultPlan::new(42).with("s", FaultKind::Err, Trigger::Prob(0.25)));
            (0..400).map(|_| fire("s").is_err()).collect()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must fire the same schedule");
        let fired = a.iter().filter(|&&f| f).count();
        assert!(
            (50..=150).contains(&fired),
            "p=0.25 over 400 hits fired {fired} times"
        );
        install(FaultPlan::new(43).with("s", FaultKind::Err, Trigger::Prob(0.25)));
        let c: Vec<bool> = (0..400).map(|_| fire("s").is_err()).collect();
        assert_ne!(a, c, "a different seed must fire a different schedule");
    }

    #[test]
    fn panic_kind_panics_and_is_catchable() {
        let _g = test_lock();
        let _d = Disarm;
        install(FaultPlan::new(1).with("s", FaultKind::Panic, Trigger::Nth(1)));
        let r = std::panic::catch_unwind(|| fire("s"));
        assert!(r.is_err(), "panic rule must unwind");
        assert!(fire("s").is_ok(), "later hits pass");
    }

    #[test]
    fn spec_grammar_roundtrip_and_rejection() {
        let p = parse_spec("7:decoder.extend=panic@0.02,a.b=slow5@0.1,c=err#3").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0].kind, FaultKind::Panic);
        assert_eq!(p.rules[0].trigger, Trigger::Prob(0.02));
        assert_eq!(p.rules[1].kind, FaultKind::Slow(5));
        assert_eq!(p.rules[2].trigger, Trigger::Nth(3));
        for bad in [
            "no-colon",
            "x:site=panic@0.5",
            "1:site=panic",
            "1:site=wat@0.5",
            "1:site=panic@1.5",
            "1:site=panic#0",
            "1:=panic@0.5:extra",
        ] {
            assert!(parse_spec(bad).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn fires_counts_hits_without_applying_the_kind() {
        let _g = test_lock();
        let _d = Disarm;
        install(FaultPlan::new(1).with("worker.wedge", FaultKind::Panic, Trigger::Nth(2)));
        // A matched hit reports true but neither panics nor errs.
        assert!(!fires("worker.wedge"));
        assert!(fires("worker.wedge"));
        assert!(!fires("worker.wedge"));
        assert_eq!(hits("worker.wedge"), 3);
        disarm();
        assert!(!fires("worker.wedge"), "disarmed sites never fire");
    }

    #[test]
    fn slow_kind_delays_but_succeeds() {
        let _g = test_lock();
        let _d = Disarm;
        install(FaultPlan::new(1).with("s", FaultKind::Slow(5), Trigger::Nth(1)));
        let t0 = std::time::Instant::now();
        fire("s").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }
}
