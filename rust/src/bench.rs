//! Hand-rolled benchmark harness (the offline dependency set has no
//! criterion): warmup, repeated samples, mean ± std, and paper-style
//! table rendering. Bench binaries (`rust/benches/*.rs`, `harness =
//! false`) use this to regenerate each of the paper's tables/figures.

use std::time::{Duration, Instant};

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    /// Per-sample wall times.
    pub samples: Vec<Duration>,
    /// Optional auxiliary metrics (decoder calls, acceptance rate…).
    pub aux: Vec<(String, f64)>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        self.samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / self.samples.len() as f64
    }

    /// Auxiliary metric by name (0.0 when absent) — the lookup the bench
    /// binaries share instead of re-rolling per-file closures.
    pub fn aux_metric(&self, key: &str) -> f64 {
        self.aux
            .iter()
            .find(|a| a.0 == key)
            .map(|a| a.1)
            .unwrap_or(0.0)
    }

    pub fn std_s(&self) -> f64 {
        let m = self.mean_s();
        let var = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - m).powi(2))
            .sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }
}

/// Run `f` `samples` times after `warmup` unrecorded runs.
pub fn measure<F: FnMut() -> Vec<(String, f64)>>(
    label: &str,
    warmup: usize,
    samples: usize,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut times = Vec::with_capacity(samples);
    let mut aux_acc: Vec<(String, f64)> = Vec::new();
    for i in 0..samples {
        let t0 = Instant::now();
        let aux = f();
        times.push(t0.elapsed());
        if i == 0 {
            aux_acc = aux;
        } else {
            for (a, b) in aux_acc.iter_mut().zip(aux) {
                a.1 += b.1;
            }
        }
    }
    for a in aux_acc.iter_mut() {
        a.1 /= samples as f64;
    }
    eprintln!(
        "  {label}: {:.3}s ± {:.3}s ({samples} samples)",
        mean_of(&times),
        std_of(&times)
    );
    Measurement {
        label: label.to_string(),
        samples: times,
        aux: aux_acc,
    }
}

fn mean_of(times: &[Duration]) -> f64 {
    times.iter().map(|d| d.as_secs_f64()).sum::<f64>() / times.len() as f64
}

fn std_of(times: &[Duration]) -> f64 {
    let m = mean_of(times);
    (times.iter().map(|d| (d.as_secs_f64() - m).powi(2)).sum::<f64>() / times.len() as f64).sqrt()
}

/// Render measurements as an aligned table; also TSV-dump to
/// `bench_out/<name>.tsv` for EXPERIMENTS.md.
pub fn report(name: &str, title: &str, rows: &[Measurement]) {
    println!("\n=== {title} ===");
    let label_w = rows.iter().map(|r| r.label.len()).max().unwrap_or(8).max(8);
    print!("{:<label_w$}  {:>12}  {:>10}", "config", "time", "std");
    if let Some(first) = rows.first() {
        for (k, _) in &first.aux {
            print!("  {k:>14}");
        }
    }
    println!();
    let mut tsv = String::from("config\tmean_s\tstd_s");
    if let Some(first) = rows.first() {
        for (k, _) in &first.aux {
            tsv.push('\t');
            tsv.push_str(k);
        }
    }
    tsv.push('\n');
    for r in rows {
        print!(
            "{:<label_w$}  {:>10.3}s  {:>9.3}s",
            r.label,
            r.mean_s(),
            r.std_s()
        );
        tsv.push_str(&format!("{}\t{:.6}\t{:.6}", r.label, r.mean_s(), r.std_s()));
        for (_, v) in &r.aux {
            print!("  {v:>14.3}");
            tsv.push_str(&format!("\t{v:.6}"));
        }
        println!();
        tsv.push('\n');
    }
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{name}.tsv");
    if std::fs::write(&path, tsv).is_ok() {
        println!("(written to {path})");
    }
}

/// Speedup helper for paper-style "X% faster" lines.
pub fn speedup(baseline: &Measurement, other: &Measurement) -> f64 {
    baseline.mean_s() / other.mean_s()
}

/// Shared setup for bench binaries and examples: vocabulary, backend and
/// test split for a task. Honours env overrides:
///   RXNSPEC_BACKEND   pjrt (default) | rust
///   RXNSPEC_DATA      data directory (default `data`)
///   RXNSPEC_ARTIFACTS artifacts directory (default `artifacts`)
pub fn eval_setup(
    task: &str,
) -> anyhow::Result<(
    crate::vocab::Vocab,
    crate::runtime::AnyBackend,
    Vec<crate::chem::Example>,
)> {
    let data = crate::knobs::DATA.raw().unwrap_or_else(|| "data".into());
    let arts = crate::knobs::ARTIFACTS.raw().unwrap_or_else(|| "artifacts".into());
    let backend_kind = crate::knobs::BACKEND.raw().unwrap_or_else(|| "pjrt".into());
    let data = std::path::Path::new(&data);
    let vocab = crate::vocab::Vocab::load(&data.join("vocab.txt"))?;
    let backend =
        crate::runtime::AnyBackend::load(&backend_kind, std::path::Path::new(&arts), task)?;
    // Compile all buckets up front so lazy compilation never lands inside
    // a timed region (idempotent; benches may call precompile again).
    backend.precompile()?;
    let split = crate::chem::read_split(&data.join(format!("{task}_test.tsv")))?;
    Ok((vocab, backend, split))
}

/// Parallel-device wall-time projection (DESIGN.md §Testbed-note,
/// EXPERIMENTS.md §Projection).
///
/// The paper's speedups assume a device (H100) where verifying N drafts in
/// one call costs ≈ one call: the batch dimension parallelizes freely
/// below saturation. This testbed has one CPU core, where effective batch
/// costs ~linearly — so we *calibrate* the per-call latency of the
/// single-row decoder at each window bucket on the real hardware, then
/// project a decode's device-parallel time as Σ over its logged calls of
/// the calibrated single-row latency (rows ≤ device capacity throughout).
/// Both real wall time and the projection are reported side by side.
pub struct DeviceModel {
    /// window bucket T → measured single-row call latency (seconds).
    latency_by_t: std::collections::BTreeMap<usize, f64>,
}

impl DeviceModel {
    /// Calibrate by timing single-row decodes against each decoder window
    /// bucket (reps ≥ 5, trimmed mean).
    pub fn calibrate(
        backend: &crate::runtime::AnyBackend,
        vocab: &crate::vocab::Vocab,
        sample_src: &str,
    ) -> anyhow::Result<DeviceModel> {
        use crate::decoding::{Backend, DecoderRow};
        let src = vocab.encode_wrapped(sample_src)?;
        let mem = backend.encode(&[&src])?;
        let t_buckets = [24usize, 48, 96];
        let mut latency_by_t = std::collections::BTreeMap::new();
        for &t in &t_buckets {
            let len = (t - 4).min(backend.dims().t_len - 1);
            let row = DecoderRow {
                tokens: std::iter::once(crate::vocab::BOS_ID)
                    .chain(std::iter::repeat(4).take(len - 1))
                    .collect(),
                mem_row: 0,
            };
            // warmup
            let _ = backend.decode(std::slice::from_ref(&row), &mem)?;
            let _ = backend.take_call_log();
            let reps = 7;
            let t0 = Instant::now();
            for _ in 0..reps {
                let _ = backend.decode(std::slice::from_ref(&row), &mem)?;
            }
            let _ = backend.take_call_log();
            latency_by_t.insert(t, t0.elapsed().as_secs_f64() / reps as f64);
        }
        Ok(DeviceModel { latency_by_t })
    }

    /// Projected device-parallel seconds for a logged call sequence.
    pub fn project(&self, calls: &[(usize, usize)]) -> f64 {
        let fallback = self
            .latency_by_t
            .values()
            .last()
            .copied()
            .unwrap_or(0.002);
        calls
            .iter()
            .map(|&(_rows, t)| self.latency_by_t.get(&t).copied().unwrap_or(fallback))
            .sum()
    }

    pub fn describe(&self) -> String {
        self.latency_by_t
            .iter()
            .map(|(t, l)| format!("T{t}={:.2}ms", l * 1000.0))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// `RXNSPEC_LIMIT` env override with a default (bench subset sizing on the
/// 1-core testbed; the paper ran full splits on an H100).
pub fn limit(default: usize) -> usize {
    crate::knobs::LIMIT.parsed_or(default)
}

/// True when the bench binary was invoked with `--json` (emit/update the
/// machine-readable `BENCH_kernels.json` perf trajectory).
pub fn json_flag() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Repo-root path of the perf-trajectory file. Cargo runs bench binaries
/// with cwd = the *package* root (`rust/`), not the workspace root, so a
/// bare relative path would write `rust/BENCH_kernels.json` and CI would
/// upload the stale committed copy. Anchored via `CARGO_MANIFEST_DIR`;
/// `RXNSPEC_BENCH_JSON` overrides for ad-hoc runs.
pub fn bench_json_path() -> std::path::PathBuf {
    match crate::knobs::BENCH_JSON.raw() {
        Some(p) => std::path::PathBuf::from(p),
        None => std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_kernels.json"),
    }
}

/// Minimal JSON support for the perf-trajectory file (`BENCH_kernels.json`).
/// The offline dependency set has no serde; this is a small hand-rolled
/// value type + parser + renderer, enough for nested objects of numbers
/// and strings, with stable key order (insertion order is preserved).
pub mod json {
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    #[derive(Debug, Clone, PartialEq)]
    pub enum Val {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Val>),
        Obj(Vec<(String, Val)>),
    }

    impl Val {
        pub fn num(x: f64) -> Val {
            Val::Num(x)
        }

        pub fn str(s: &str) -> Val {
            Val::Str(s.to_string())
        }

        pub fn obj(entries: Vec<(String, Val)>) -> Val {
            Val::Obj(entries)
        }

        /// Object member lookup.
        pub fn get(&self, key: &str) -> Option<&Val> {
            match self {
                Val::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }

        /// Insert-or-replace an object member (keeps first-insert order).
        pub fn set(&mut self, key: &str, val: Val) {
            if let Val::Obj(entries) = self {
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = val;
                } else {
                    entries.push((key.to_string(), val));
                }
            }
        }

        pub fn render(&self) -> String {
            let mut s = String::new();
            self.render_into(&mut s, 0);
            s
        }

        fn render_into(&self, out: &mut String, indent: usize) {
            match self {
                Val::Null => out.push_str("null"),
                Val::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
                Val::Num(x) => {
                    if !x.is_finite() {
                        out.push_str("null");
                    } else if x.fract() == 0.0 && x.abs() < 1e15 {
                        out.push_str(&format!("{:.0}", x));
                    } else {
                        out.push_str(&format!("{}", x));
                    }
                }
                Val::Str(s) => render_str(s, out),
                Val::Arr(items) => {
                    if items.is_empty() {
                        out.push_str("[]");
                        return;
                    }
                    out.push('[');
                    for (i, v) in items.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                        v.render_into(out, indent + 1);
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                    out.push(']');
                }
                Val::Obj(entries) => {
                    if entries.is_empty() {
                        out.push_str("{}");
                        return;
                    }
                    out.push('{');
                    for (i, (k, v)) in entries.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                        render_str(k, out);
                        out.push_str(": ");
                        v.render_into(out, indent + 1);
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                    out.push('}');
                }
            }
        }
    }

    fn render_str(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
    }

    pub fn parse(s: &str) -> Result<Val> {
        let b: Vec<char> = s.chars().collect();
        let mut i = 0usize;
        let v = parse_val(&b, &mut i)?;
        skip_ws(&b, &mut i);
        if i != b.len() {
            bail!("trailing characters at offset {i}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[char], i: &mut usize) {
        while *i < b.len() && b[*i].is_whitespace() {
            *i += 1;
        }
    }

    fn expect(b: &[char], i: &mut usize, c: char) -> Result<()> {
        skip_ws(b, i);
        if *i < b.len() && b[*i] == c {
            *i += 1;
            Ok(())
        } else {
            bail!("expected {c:?} at offset {}", *i)
        }
    }

    fn parse_val(b: &[char], i: &mut usize) -> Result<Val> {
        skip_ws(b, i);
        match b.get(*i) {
            None => bail!("unexpected end of input"),
            Some('{') => {
                *i += 1;
                let mut entries = Vec::new();
                skip_ws(b, i);
                if b.get(*i) == Some(&'}') {
                    *i += 1;
                    return Ok(Val::Obj(entries));
                }
                loop {
                    skip_ws(b, i);
                    let key = parse_string(b, i)?;
                    expect(b, i, ':')?;
                    let v = parse_val(b, i)?;
                    entries.push((key, v));
                    skip_ws(b, i);
                    match b.get(*i) {
                        Some(',') => *i += 1,
                        Some('}') => {
                            *i += 1;
                            return Ok(Val::Obj(entries));
                        }
                        _ => bail!("expected ',' or '}}' at offset {}", *i),
                    }
                }
            }
            Some('[') => {
                *i += 1;
                let mut items = Vec::new();
                skip_ws(b, i);
                if b.get(*i) == Some(&']') {
                    *i += 1;
                    return Ok(Val::Arr(items));
                }
                loop {
                    items.push(parse_val(b, i)?);
                    skip_ws(b, i);
                    match b.get(*i) {
                        Some(',') => *i += 1,
                        Some(']') => {
                            *i += 1;
                            return Ok(Val::Arr(items));
                        }
                        _ => bail!("expected ',' or ']' at offset {}", *i),
                    }
                }
            }
            Some('"') => Ok(Val::Str(parse_string(b, i)?)),
            Some('t') if matches(b, *i, "true") => {
                *i += 4;
                Ok(Val::Bool(true))
            }
            Some('f') if matches(b, *i, "false") => {
                *i += 5;
                Ok(Val::Bool(false))
            }
            Some('n') if matches(b, *i, "null") => {
                *i += 4;
                Ok(Val::Null)
            }
            Some(_) => {
                let start = *i;
                while *i < b.len()
                    && (b[*i].is_ascii_digit()
                        || matches!(b[*i], '-' | '+' | '.' | 'e' | 'E'))
                {
                    *i += 1;
                }
                if start == *i {
                    bail!("unexpected character at offset {start}");
                }
                let tok: String = b[start..*i].iter().collect();
                Ok(Val::Num(tok.parse::<f64>().context("bad number")?))
            }
        }
    }

    fn matches(b: &[char], i: usize, word: &str) -> bool {
        word.chars()
            .enumerate()
            .all(|(k, c)| b.get(i + k) == Some(&c))
    }

    fn parse_string(b: &[char], i: &mut usize) -> Result<String> {
        skip_ws(b, i);
        if b.get(*i) != Some(&'"') {
            bail!("expected string at offset {}", *i);
        }
        *i += 1;
        let mut s = String::new();
        while let Some(&c) = b.get(*i) {
            *i += 1;
            match c {
                '"' => return Ok(s),
                '\\' => {
                    let e = *b.get(*i).context("dangling escape")?;
                    *i += 1;
                    match e {
                        '"' => s.push('"'),
                        '\\' => s.push('\\'),
                        '/' => s.push('/'),
                        'n' => s.push('\n'),
                        't' => s.push('\t'),
                        'r' => s.push('\r'),
                        'b' => s.push('\u{8}'),
                        'f' => s.push('\u{c}'),
                        'u' => {
                            if *i + 4 > b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex: String = b[*i..*i + 4].iter().collect();
                            *i += 4;
                            let cp = u32::from_str_radix(&hex, 16).context("bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("unknown escape \\{other}"),
                    }
                }
                c => s.push(c),
            }
        }
        bail!("unterminated string")
    }

    /// Read `path` (an object; created if missing), merge `entries` into
    /// the top-level member `section`, write it back. Every `--json`
    /// bench run updates only its own section, so the perf trajectory
    /// accumulates across benches without clobbering — and **within** a
    /// section the merge is key-wise: a partial run (smoke sweeps, a
    /// bench aborted halfway, a dispatch leg that measures fewer shapes)
    /// overwrites only the metrics it re-measured and never drops the
    /// rest of the section. An existing file that fails to parse (or
    /// whose root is not an object) is an **error**, never silently
    /// overwritten — a truncated or hand-mangled trajectory must be
    /// fixed or deleted explicitly.
    pub fn merge_section(path: &Path, section: &str, entries: Val) -> Result<()> {
        let mut root = match std::fs::read_to_string(path) {
            Ok(body) => match parse(&body) {
                Ok(v @ Val::Obj(_)) => v,
                Ok(_) => bail!(
                    "{}: root is not a JSON object; refusing to overwrite",
                    path.display()
                ),
                Err(e) => bail!(
                    "{}: unparsable ({e}); fix or delete it before re-running with --json",
                    path.display()
                ),
            },
            Err(_) => Val::Obj(Vec::new()),
        };
        let merged = match (root.get(section), entries) {
            (Some(old @ Val::Obj(_)), Val::Obj(new_entries)) => {
                let mut m = old.clone();
                for (k, v) in new_entries {
                    m.set(&k, v);
                }
                m
            }
            (_, entries) => entries,
        };
        root.set(section, merged);
        let body = root.render() + "\n";
        std::fs::write(path, body).with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn roundtrip_nested_object() {
            let src = r#"{"a": 1.5, "b": {"c": [1, 2, "x\n"], "d": true}, "e": null}"#;
            let v = parse(src).unwrap();
            assert_eq!(v.get("a"), Some(&Val::Num(1.5)));
            let reparsed = parse(&v.render()).unwrap();
            assert_eq!(v, reparsed);
        }

        #[test]
        fn set_replaces_and_appends() {
            let mut v = Val::obj(vec![("x".into(), Val::num(1.0))]);
            v.set("x", Val::num(2.0));
            v.set("y", Val::str("hi"));
            assert_eq!(v.get("x"), Some(&Val::Num(2.0)));
            assert_eq!(v.get("y"), Some(&Val::Str("hi".into())));
        }

        #[test]
        fn merge_section_accumulates_across_writes() {
            let dir = std::env::temp_dir().join("rxnspec_json_test");
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join("bench.json");
            let _ = std::fs::remove_file(&p);
            merge_section(&p, "a", Val::obj(vec![("k".into(), Val::num(1.0))])).unwrap();
            merge_section(&p, "b", Val::obj(vec![("k".into(), Val::num(2.0))])).unwrap();
            merge_section(&p, "a", Val::obj(vec![("k".into(), Val::num(3.0))])).unwrap();
            let root = parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
            assert_eq!(root.get("a").unwrap().get("k"), Some(&Val::Num(3.0)));
            assert_eq!(root.get("b").unwrap().get("k"), Some(&Val::Num(2.0)));
        }

        #[test]
        fn merge_section_unions_keys_within_a_section() {
            // A partial run must overwrite only the metrics it
            // re-measured, never drop the rest of the section.
            let dir = std::env::temp_dir().join("rxnspec_json_test");
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join("partial.json");
            let _ = std::fs::remove_file(&p);
            merge_section(
                &p,
                "kernel_micro",
                Val::obj(vec![
                    ("gemm_ns".into(), Val::num(100.0)),
                    ("greedy_tok_s".into(), Val::num(50.0)),
                ]),
            )
            .unwrap();
            // Partial re-run: only one key re-measured.
            merge_section(
                &p,
                "kernel_micro",
                Val::obj(vec![("gemm_ns".into(), Val::num(90.0))]),
            )
            .unwrap();
            let root = parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
            let sec = root.get("kernel_micro").unwrap();
            assert_eq!(sec.get("gemm_ns"), Some(&Val::Num(90.0)));
            assert_eq!(sec.get("greedy_tok_s"), Some(&Val::Num(50.0)));
        }

        #[test]
        fn merge_section_refuses_to_clobber_broken_files() {
            let dir = std::env::temp_dir().join("rxnspec_json_test");
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join("broken.json");
            std::fs::write(&p, "{\"a\": 1,}").unwrap(); // trailing comma
            let before = std::fs::read_to_string(&p).unwrap();
            assert!(merge_section(&p, "b", Val::obj(vec![])).is_err());
            assert_eq!(std::fs::read_to_string(&p).unwrap(), before);

            let p2 = dir.join("nonobj.json");
            std::fs::write(&p2, "[1, 2]").unwrap();
            assert!(merge_section(&p2, "b", Val::obj(vec![])).is_err());
        }

        #[test]
        fn numbers_render_cleanly() {
            assert_eq!(Val::num(3.0).render(), "3");
            assert_eq!(Val::num(0.25).render(), "0.25");
            assert_eq!(Val::num(f64::NAN).render(), "null");
        }

        #[test]
        fn rejects_malformed_input() {
            assert!(parse("{").is_err());
            assert!(parse(r#"{"a" 1}"#).is_err());
            assert!(parse("[1, 2,]").is_err());
            assert!(parse("nope").is_err());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples_and_aux() {
        let mut n = 0u64;
        let m = measure("t", 1, 3, || {
            n += 1;
            std::thread::sleep(Duration::from_millis(1));
            vec![("calls".to_string(), 2.0)]
        });
        assert_eq!(m.samples.len(), 3);
        assert_eq!(n, 4); // warmup + samples
        assert!(m.mean_s() >= 0.001);
        assert_eq!(m.aux, vec![("calls".to_string(), 2.0)]);
    }

    #[test]
    fn speedup_ratio() {
        let a = Measurement {
            label: "a".into(),
            samples: vec![Duration::from_millis(100)],
            aux: vec![],
        };
        let b = Measurement {
            label: "b".into(),
            samples: vec![Duration::from_millis(50)],
            aux: vec![],
        };
        assert!((speedup(&a, &b) - 2.0).abs() < 1e-9);
    }
}
