//! Hand-rolled benchmark harness (the offline dependency set has no
//! criterion): warmup, repeated samples, mean ± std, and paper-style
//! table rendering. Bench binaries (`rust/benches/*.rs`, `harness =
//! false`) use this to regenerate each of the paper's tables/figures.

use std::time::{Duration, Instant};

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    /// Per-sample wall times.
    pub samples: Vec<Duration>,
    /// Optional auxiliary metrics (decoder calls, acceptance rate…).
    pub aux: Vec<(String, f64)>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        self.samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / self.samples.len() as f64
    }

    pub fn std_s(&self) -> f64 {
        let m = self.mean_s();
        let var = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - m).powi(2))
            .sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }
}

/// Run `f` `samples` times after `warmup` unrecorded runs.
pub fn measure<F: FnMut() -> Vec<(String, f64)>>(
    label: &str,
    warmup: usize,
    samples: usize,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut times = Vec::with_capacity(samples);
    let mut aux_acc: Vec<(String, f64)> = Vec::new();
    for i in 0..samples {
        let t0 = Instant::now();
        let aux = f();
        times.push(t0.elapsed());
        if i == 0 {
            aux_acc = aux;
        } else {
            for (a, b) in aux_acc.iter_mut().zip(aux) {
                a.1 += b.1;
            }
        }
    }
    for a in aux_acc.iter_mut() {
        a.1 /= samples as f64;
    }
    eprintln!(
        "  {label}: {:.3}s ± {:.3}s ({samples} samples)",
        mean_of(&times),
        std_of(&times)
    );
    Measurement {
        label: label.to_string(),
        samples: times,
        aux: aux_acc,
    }
}

fn mean_of(times: &[Duration]) -> f64 {
    times.iter().map(|d| d.as_secs_f64()).sum::<f64>() / times.len() as f64
}

fn std_of(times: &[Duration]) -> f64 {
    let m = mean_of(times);
    (times.iter().map(|d| (d.as_secs_f64() - m).powi(2)).sum::<f64>() / times.len() as f64).sqrt()
}

/// Render measurements as an aligned table; also TSV-dump to
/// `bench_out/<name>.tsv` for EXPERIMENTS.md.
pub fn report(name: &str, title: &str, rows: &[Measurement]) {
    println!("\n=== {title} ===");
    let label_w = rows.iter().map(|r| r.label.len()).max().unwrap_or(8).max(8);
    print!("{:<label_w$}  {:>12}  {:>10}", "config", "time", "std");
    if let Some(first) = rows.first() {
        for (k, _) in &first.aux {
            print!("  {k:>14}");
        }
    }
    println!();
    let mut tsv = String::from("config\tmean_s\tstd_s");
    if let Some(first) = rows.first() {
        for (k, _) in &first.aux {
            tsv.push('\t');
            tsv.push_str(k);
        }
    }
    tsv.push('\n');
    for r in rows {
        print!(
            "{:<label_w$}  {:>10.3}s  {:>9.3}s",
            r.label,
            r.mean_s(),
            r.std_s()
        );
        tsv.push_str(&format!("{}\t{:.6}\t{:.6}", r.label, r.mean_s(), r.std_s()));
        for (_, v) in &r.aux {
            print!("  {v:>14.3}");
            tsv.push_str(&format!("\t{v:.6}"));
        }
        println!();
        tsv.push('\n');
    }
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{name}.tsv");
    if std::fs::write(&path, tsv).is_ok() {
        println!("(written to {path})");
    }
}

/// Speedup helper for paper-style "X% faster" lines.
pub fn speedup(baseline: &Measurement, other: &Measurement) -> f64 {
    baseline.mean_s() / other.mean_s()
}

/// Shared setup for bench binaries and examples: vocabulary, backend and
/// test split for a task. Honours env overrides:
///   RXNSPEC_BACKEND   pjrt (default) | rust
///   RXNSPEC_DATA      data directory (default `data`)
///   RXNSPEC_ARTIFACTS artifacts directory (default `artifacts`)
pub fn eval_setup(
    task: &str,
) -> anyhow::Result<(
    crate::vocab::Vocab,
    crate::runtime::AnyBackend,
    Vec<crate::chem::Example>,
)> {
    let data = std::env::var("RXNSPEC_DATA").unwrap_or_else(|_| "data".into());
    let arts = std::env::var("RXNSPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let backend_kind = std::env::var("RXNSPEC_BACKEND").unwrap_or_else(|_| "pjrt".into());
    let data = std::path::Path::new(&data);
    let vocab = crate::vocab::Vocab::load(&data.join("vocab.txt"))?;
    let backend =
        crate::runtime::AnyBackend::load(&backend_kind, std::path::Path::new(&arts), task)?;
    // Compile all buckets up front so lazy compilation never lands inside
    // a timed region (idempotent; benches may call precompile again).
    backend.precompile()?;
    let split = crate::chem::read_split(&data.join(format!("{task}_test.tsv")))?;
    Ok((vocab, backend, split))
}

/// Parallel-device wall-time projection (DESIGN.md §Testbed-note,
/// EXPERIMENTS.md §Projection).
///
/// The paper's speedups assume a device (H100) where verifying N drafts in
/// one call costs ≈ one call: the batch dimension parallelizes freely
/// below saturation. This testbed has one CPU core, where effective batch
/// costs ~linearly — so we *calibrate* the per-call latency of the
/// single-row decoder at each window bucket on the real hardware, then
/// project a decode's device-parallel time as Σ over its logged calls of
/// the calibrated single-row latency (rows ≤ device capacity throughout).
/// Both real wall time and the projection are reported side by side.
pub struct DeviceModel {
    /// window bucket T → measured single-row call latency (seconds).
    latency_by_t: std::collections::BTreeMap<usize, f64>,
}

impl DeviceModel {
    /// Calibrate by timing single-row decodes against each decoder window
    /// bucket (reps ≥ 5, trimmed mean).
    pub fn calibrate(
        backend: &crate::runtime::AnyBackend,
        vocab: &crate::vocab::Vocab,
        sample_src: &str,
    ) -> anyhow::Result<DeviceModel> {
        use crate::decoding::{Backend, DecoderRow};
        let src = vocab.encode_wrapped(sample_src)?;
        let mem = backend.encode(&[&src])?;
        let t_buckets = [24usize, 48, 96];
        let mut latency_by_t = std::collections::BTreeMap::new();
        for &t in &t_buckets {
            let len = (t - 4).min(backend.dims().t_len - 1);
            let row = DecoderRow {
                tokens: std::iter::once(crate::vocab::BOS_ID)
                    .chain(std::iter::repeat(4).take(len - 1))
                    .collect(),
                mem_row: 0,
            };
            // warmup
            let _ = backend.decode(std::slice::from_ref(&row), &mem)?;
            let _ = backend.take_call_log();
            let reps = 7;
            let t0 = Instant::now();
            for _ in 0..reps {
                let _ = backend.decode(std::slice::from_ref(&row), &mem)?;
            }
            let _ = backend.take_call_log();
            latency_by_t.insert(t, t0.elapsed().as_secs_f64() / reps as f64);
        }
        Ok(DeviceModel { latency_by_t })
    }

    /// Projected device-parallel seconds for a logged call sequence.
    pub fn project(&self, calls: &[(usize, usize)]) -> f64 {
        let fallback = self
            .latency_by_t
            .values()
            .last()
            .copied()
            .unwrap_or(0.002);
        calls
            .iter()
            .map(|&(_rows, t)| self.latency_by_t.get(&t).copied().unwrap_or(fallback))
            .sum()
    }

    pub fn describe(&self) -> String {
        self.latency_by_t
            .iter()
            .map(|(t, l)| format!("T{t}={:.2}ms", l * 1000.0))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// `RXNSPEC_LIMIT` env override with a default (bench subset sizing on the
/// 1-core testbed; the paper ran full splits on an H100).
pub fn limit(default: usize) -> usize {
    std::env::var("RXNSPEC_LIMIT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples_and_aux() {
        let mut n = 0u64;
        let m = measure("t", 1, 3, || {
            n += 1;
            std::thread::sleep(Duration::from_millis(1));
            vec![("calls".to_string(), 2.0)]
        });
        assert_eq!(m.samples.len(), 3);
        assert_eq!(n, 4); // warmup + samples
        assert!(m.mean_s() >= 0.001);
        assert_eq!(m.aux, vec![("calls".to_string(), 2.0)]);
    }

    #[test]
    fn speedup_ratio() {
        let a = Measurement {
            label: "a".into(),
            samples: vec![Duration::from_millis(100)],
            aux: vec![],
        };
        let b = Measurement {
            label: "b".into(),
            samples: vec![Duration::from_millis(50)],
            aux: vec![],
        };
        assert!((speedup(&a, &b) - 2.0).abs() < 1e-9);
    }
}
