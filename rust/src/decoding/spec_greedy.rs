//! Speculative greedy decoding (§2.1, Figure 2) on incremental sessions.
//!
//! At every step, every draft is concatenated to the current prefix and the
//! whole set is verified in **one** decoder forward pass (drafts inflate the
//! effective batch). The draft with the longest accepted prefix wins; its
//! accepted tokens plus one fresh argmax token are emitted, so each call
//! advances the sequence by 1..=DL+1 tokens. The produced sequence is
//! token-exact equal to standard greedy decoding — speculative decoding
//! "does not affect the content of the predicted sequence in any way".
//!
//! Session mechanics per step and per query: the committed prefix row is
//! [`fork`](super::DecoderSession::fork)ed once per draft and each fork is
//! extended by `pending ‖ draft` (a KV-cached backend computes only that
//! window). The winning fork is [`truncate`](super::DecoderSession::truncate)d
//! back to the accepted length and becomes the new committed row; the
//! losers are released.

use std::time::Instant;

use anyhow::Result;

use crate::draft::{extract_drafts_merged, Acceptance, Draft, DraftConfig, DraftSource};
use crate::trace::{self, Phase};
use crate::trace_span;
use crate::vocab::{BOS_ID, EOS_ID};

use super::{
    clip_draft, Backend, DecodeOutput, DecodeStats, DecoderSession, Hypothesis, SessionStats,
};

struct SpecLane {
    /// Committed session row (length `sess_len`).
    row: usize,
    /// BOS + emitted tokens (the trailing fresh token is not yet
    /// committed to the session; it rides into the next step's delta).
    tokens: Vec<i64>,
    sess_len: usize,
    drafts: Vec<Draft>,
    score: f64,
    done: bool,
    accepted: usize,
    /// Per-source split of `accepted` (query-copy vs corpus windows).
    accepted_query: usize,
    accepted_corpus: usize,
}

/// A live speculative-greedy decode over a [`DecoderSession`].
pub struct SpecGreedyRun<'a> {
    sess: Box<dyn DecoderSession + 'a>,
    cfg: DraftConfig,
    /// Corpus-learned windows (`cache::DraftStore::top_k`) merged behind
    /// each lane's query-copy drafts under the shared `max_drafts` cap.
    corpus: Vec<Vec<i64>>,
    lanes: Vec<SpecLane>,
    calls: usize,
    rows_submitted: usize,
}

impl<'a> SpecGreedyRun<'a> {
    pub fn new(sess: Box<dyn DecoderSession + 'a>, cfg: DraftConfig) -> SpecGreedyRun<'a> {
        SpecGreedyRun::with_corpus(sess, cfg, Vec::new())
    }

    /// A run whose lanes additionally draft from corpus-learned windows.
    /// Output is unchanged for any corpus content — drafts only propose;
    /// the accept rule keeps the emitted sequence exactly greedy.
    pub fn with_corpus(
        sess: Box<dyn DecoderSession + 'a>,
        cfg: DraftConfig,
        corpus: Vec<Vec<i64>>,
    ) -> SpecGreedyRun<'a> {
        SpecGreedyRun {
            sess,
            cfg,
            corpus,
            lanes: Vec::new(),
            calls: 0,
            rows_submitted: 0,
        }
    }

    pub fn session_mut(&mut self) -> &mut (dyn DecoderSession + 'a) {
        &mut *self.sess
    }

    /// Add a lane for the BOS/EOS-wrapped query `src` decoding against
    /// `mem_row`. Drafts come from the query *without* its wrapping.
    pub fn admit(&mut self, mem_row: usize, src: &[i64]) -> usize {
        let inner: Vec<i64> = src
            .iter()
            .copied()
            .filter(|&t| t != BOS_ID && t != EOS_ID)
            .collect();
        let row = self.sess.new_row(mem_row);
        self.lanes.push(SpecLane {
            row,
            tokens: vec![BOS_ID],
            sess_len: 0,
            drafts: extract_drafts_merged(&inner, &self.cfg, &self.corpus),
            score: 0.0,
            done: false,
            accepted: 0,
            accepted_query: 0,
            accepted_corpus: 0,
        });
        self.lanes.len() - 1
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn n_live(&self) -> usize {
        self.lanes.iter().filter(|l| !l.done).count()
    }

    pub fn finished(&self) -> bool {
        self.lanes.iter().all(|l| l.done)
    }

    pub fn calls(&self) -> usize {
        self.calls
    }

    pub fn rows_submitted(&self) -> usize {
        self.rows_submitted
    }

    pub fn session_stats(&self) -> SessionStats {
        self.sess.stats()
    }

    /// Per-lane acceptance accounting (total includes the EOS step, as
    /// the paper counts it).
    pub fn lane_acceptance(&self, lane: usize) -> Acceptance {
        let l = &self.lanes[lane];
        Acceptance {
            accepted_draft_tokens: l.accepted,
            total_tokens: self.hypothesis(lane).tokens.len() + 1,
        }
    }

    /// Per-lane accepted-token split: `(query_copy, corpus)`. The two
    /// always sum to `lane_acceptance(lane).accepted_draft_tokens`.
    pub fn lane_source_acceptance(&self, lane: usize) -> (usize, usize) {
        let l = &self.lanes[lane];
        (l.accepted_query, l.accepted_corpus)
    }

    /// One speculative step across all live lanes (one decoder call over
    /// `Σ_live |drafts|` fork rows). Returns the lanes that finished.
    pub fn step(&mut self) -> Result<Vec<usize>> {
        let t_len = self.sess.dims().t_len;

        // concatDraftsToSequences: fork the committed row per draft and
        // extend each fork by pending ‖ clipped draft.
        let mut frows: Vec<usize> = Vec::new();
        let mut delta_buf: Vec<Vec<i64>> = Vec::new();
        // (lane, draft index, clipped length) per fork row.
        let mut meta: Vec<(usize, usize, usize)> = Vec::new();
        let mut fork_span = trace_span!(Phase::Fork);
        for li in 0..self.lanes.len() {
            if self.lanes[li].done {
                continue;
            }
            let n_drafts = self.lanes[li].drafts.len();
            for di in 0..n_drafts {
                let lane = &self.lanes[li];
                let clipped = clip_draft(&lane.drafts[di].tokens, lane.tokens.len(), t_len);
                let mut delta = lane.tokens[lane.sess_len..].to_vec();
                delta.extend_from_slice(clipped);
                let clen = clipped.len();
                frows.push(self.sess.fork(lane.row));
                delta_buf.push(delta);
                meta.push((li, di, clen));
            }
        }
        if let Some(s) = fork_span.as_mut() {
            s.set_payload(frows.len() as u64);
        }
        drop(fork_span);
        if frows.is_empty() {
            return Ok(Vec::new());
        }
        let deltas: Vec<(usize, &[i64])> = frows
            .iter()
            .zip(&delta_buf)
            .map(|(&r, d)| (r, d.as_slice()))
            .collect();
        crate::faults::fire("decoder.extend")?;
        let lp = {
            let _ext = trace_span!(Phase::Extend, deltas.len() as u64);
            self.sess.extend(&deltas)?
        };
        self.calls += 1;
        self.rows_submitted += deltas.len();
        drop(deltas);

        // selectBestDraft: per lane, the fork with the most accepted
        // tokens (ties → first).
        let mut verify_span = trace_span!(Phase::Verify);
        let mut best: Vec<Option<(usize, usize)>> = vec![None; self.lanes.len()]; // (meta idx, k)
        for (r, &(li, di, clen)) in meta.iter().enumerate() {
            let lane = &self.lanes[li];
            let p = lane.tokens.len();
            let draft = &lane.drafts[di].tokens;
            let mut k = 0usize;
            while k < clen {
                if lp.argmax(r, p - 1 + k) != draft[k] {
                    break;
                }
                k += 1;
            }
            match best[li] {
                Some((_, bk)) if bk >= k => {}
                _ => best[li] = Some((r, k)),
            }
        }
        if let Some(s) = verify_span.as_mut() {
            // Payload: draft tokens the winning forks accepted this step
            // (per-source splits accumulate on the lanes below).
            s.set_payload(best.iter().flatten().map(|&(_, k)| k as u64).sum());
        }
        drop(verify_span);

        // Emit accepted tokens + one fresh argmax per lane, then swap the
        // committed session row to the winning fork (truncated back to
        // the accepted length) and release the losers.
        let _tr = trace_span!(Phase::Truncate);
        let mut just_finished = Vec::new();
        for li in 0..self.lanes.len() {
            let Some((r, k)) = best[li] else { continue };
            let (emitted, old_row, win_source) = {
                let lane = &self.lanes[li];
                let p = lane.tokens.len();
                let (_, di, _) = meta[r];
                let mut e: Vec<i64> = lane.drafts[di].tokens[..k].to_vec();
                e.push(lp.argmax(r, p - 1 + k));
                (e, lane.row, lane.drafts[di].source)
            };
            let p = self.lanes[li].tokens.len();
            {
                let lane = &mut self.lanes[li];
                for (idx, &tok) in emitted.iter().enumerate() {
                    lane.score += lp.logp(r, p - 1 + idx, tok) as f64;
                    lane.tokens.push(tok);
                    if tok == EOS_ID {
                        lane.done = true;
                        break;
                    }
                    if idx < k {
                        lane.accepted += 1;
                        match win_source {
                            DraftSource::QueryCopy => lane.accepted_query += 1,
                            DraftSource::Corpus => lane.accepted_corpus += 1,
                            DraftSource::Sentinel => {}
                        }
                    }
                    if lane.tokens.len() >= t_len {
                        lane.done = true;
                        break;
                    }
                }
            }
            // Winning fork keeps the verified prefix p + k; everything
            // else computed for it this step is rolled back.
            let win = frows[r];
            self.sess.truncate(win, p + k);
            self.sess.release(old_row);
            let lane = &mut self.lanes[li];
            lane.row = win;
            lane.sess_len = (p + k).min(lane.tokens.len());
            if lane.done {
                just_finished.push(li);
                self.sess.release(win);
            }
        }
        // Release losing forks.
        for (r, &(li, _, _)) in meta.iter().enumerate() {
            if best[li].map(|(br, _)| br) != Some(r) {
                self.sess.release(frows[r]);
            }
        }
        Ok(just_finished)
    }

    /// Hypothesis of a lane: generated tokens, truncated at EOS.
    pub fn hypothesis(&self, lane: usize) -> Hypothesis {
        let l = &self.lanes[lane];
        let mut tokens: Vec<i64> = l.tokens[1..].to_vec();
        if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
            tokens.truncate(pos);
        }
        Hypothesis {
            tokens,
            score: l.score,
        }
    }
}

/// Speculatively greedy-decode one query (batch size 1).
pub fn spec_greedy<B: Backend>(
    backend: &B,
    src: &[i64],
    cfg: &DraftConfig,
) -> Result<DecodeOutput> {
    let mut out = spec_greedy_batch(backend, &[src], cfg)?;
    Ok(out.pop().unwrap())
}

/// [`spec_greedy`] with corpus-learned drafts merged behind the query
/// copies. Output is token-exact vs [`greedy`](super::greedy) for any
/// corpus content.
pub fn spec_greedy_corpus<B: Backend>(
    backend: &B,
    src: &[i64],
    cfg: &DraftConfig,
    corpus: &[Vec<i64>],
) -> Result<DecodeOutput> {
    let mut out = spec_greedy_batch_corpus(backend, &[src], cfg, corpus)?;
    Ok(out.pop().unwrap())
}

/// Speculative greedy decoding over a batch of queries.
///
/// Every live query contributes `|drafts|` rows per call, so the effective
/// batch is `Σ_live |drafts_i|` — the §3.3 "effective batch inflation".
/// The number of calls is set by the least-lucky sequence: rows for
/// finished queries are dropped, but a call happens while any query lives.
pub fn spec_greedy_batch<B: Backend>(
    backend: &B,
    srcs: &[&[i64]],
    cfg: &DraftConfig,
) -> Result<Vec<DecodeOutput>> {
    spec_greedy_batch_corpus(backend, srcs, cfg, &[])
}

/// [`spec_greedy_batch`] with an additional corpus draft source.
pub fn spec_greedy_batch_corpus<B: Backend>(
    backend: &B,
    srcs: &[&[i64]],
    cfg: &DraftConfig,
    corpus: &[Vec<i64>],
) -> Result<Vec<DecodeOutput>> {
    let t0 = Instant::now();
    let ph0 = trace::thread_phase_ns();
    let memory = {
        let _enc = trace_span!(Phase::Encode, srcs.len() as u64);
        backend.encode(srcs)?
    };
    let n = srcs.len();
    let sess = {
        let _beg = trace_span!(Phase::SessionBegin);
        backend.begin(memory)?
    };
    let mut run = SpecGreedyRun::with_corpus(sess, cfg.clone(), corpus.to_vec());
    for (i, src) in srcs.iter().enumerate() {
        run.admit(i, src);
    }
    while !run.finished() {
        run.step()?;
    }
    let wall = t0.elapsed();
    // Trace-layer phase attribution, apportioned per query like `wall`;
    // zero when RXNSPEC_TRACE is off (see greedy_batch).
    let ph1 = trace::thread_phase_ns();
    let phase_us =
        |p: Phase| ph1[p as usize].saturating_sub(ph0[p as usize]) / 1000 / n as u64;

    let sess = run.session_stats();
    let base = DecodeStats {
        decoder_calls: run.calls(),
        encoder_calls: 1,
        decoder_rows: run.rows_submitted(),
        tokens_computed: sess.tokens_computed,
        tokens_reused: sess.tokens_reused,
        encode_us: phase_us(Phase::Encode),
        extend_us: phase_us(Phase::Extend),
        verify_us: phase_us(Phase::Verify),
        ..Default::default()
    };
    Ok((0..n)
        .map(|q| {
            let hyp = run.hypothesis(q);
            let mut s = base;
            s.wall = wall / n as u32;
            s.acceptance = run.lane_acceptance(q);
            let (aq, ac) = run.lane_source_acceptance(q);
            s.accepted_query_tokens = aq;
            s.accepted_corpus_tokens = ac;
            DecodeOutput {
                hyps: vec![hyp],
                stats: s,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::greedy;
    use crate::rng::Rng;
    use crate::testutil::{random_wrapped_src, CopyModel, HashModel};

    /// THE core invariant (paper §2.1): speculative decoding does not
    /// change the produced sequence in any way.
    #[test]
    fn prop_spec_greedy_token_exact_vs_greedy_hash_model() {
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..30 {
            let m = HashModel::new(64, 64, 32, case);
            let src = random_wrapped_src(&mut rng, 4, 20, 32);
            let g = greedy(&m, &src).unwrap();
            for dl in [0usize, 2, 4, 10] {
                let s = spec_greedy(&m, &src, &DraftConfig::new(dl)).unwrap();
                assert_eq!(
                    s.hyps[0].tokens, g.hyps[0].tokens,
                    "case {case} dl {dl}: speculative output diverged"
                );
                assert!(
                    s.stats.decoder_calls <= g.stats.decoder_calls,
                    "speculative used more calls than greedy"
                );
            }
        }
    }

    #[test]
    fn copy_model_accepts_most_draft_tokens() {
        // CopyModel's target literally contains source substrings, so with
        // reasonable DL the acceptance rate should be high and calls should
        // drop well below the token count.
        let m = CopyModel::new(96, 96, 40);
        let src = vec![
            BOS_ID, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23, EOS_ID,
        ];
        let g = greedy(&m, &src).unwrap();
        let s = spec_greedy(&m, &src, &DraftConfig::new(6)).unwrap();
        assert_eq!(s.hyps[0].tokens, g.hyps[0].tokens);
        assert!(
            s.stats.decoder_calls * 2 <= g.stats.decoder_calls,
            "expected ≥2x fewer calls: {} vs {}",
            s.stats.decoder_calls,
            g.stats.decoder_calls
        );
        assert!(s.stats.acceptance.rate() > 0.5, "rate {}", s.stats.acceptance.rate());
    }

    #[test]
    fn dl_zero_is_plain_greedy_in_calls_and_tokens() {
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, EOS_ID];
        let g = greedy(&m, &src).unwrap();
        let s = spec_greedy(&m, &src, &DraftConfig::new(0)).unwrap();
        assert_eq!(s.hyps[0].tokens, g.hyps[0].tokens);
        assert_eq!(s.stats.decoder_calls, g.stats.decoder_calls);
        assert_eq!(s.stats.acceptance.accepted_draft_tokens, 0);
    }

    #[test]
    fn corpus_drafts_attributed_and_exact() {
        // CopyModel's target is the inner query verbatim. A query shorter
        // than DL yields no query windows, so acceptance must come from
        // the corpus source alone — and the output stays exactly greedy.
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, 14, EOS_ID];
        let g = greedy(&m, &src).unwrap();
        let corpus = vec![vec![10, 11, 12], vec![12, 13, 14]];
        let s = spec_greedy_corpus(&m, &src, &DraftConfig::new(10), &corpus).unwrap();
        assert_eq!(s.hyps[0].tokens, g.hyps[0].tokens);
        assert_eq!(s.stats.accepted_query_tokens, 0);
        assert!(s.stats.accepted_corpus_tokens > 0);
        assert_eq!(
            s.stats.accepted_query_tokens + s.stats.accepted_corpus_tokens,
            s.stats.acceptance.accepted_draft_tokens
        );
        assert!(
            s.stats.decoder_calls < g.stats.decoder_calls,
            "corpus drafts should cut calls on the copy regime"
        );
    }

    #[test]
    fn batch_spec_matches_singles() {
        let m = HashModel::new(64, 64, 32, 7);
        let mut rng = Rng::new(5);
        let a = random_wrapped_src(&mut rng, 6, 18, 32);
        let b = random_wrapped_src(&mut rng, 6, 18, 32);
        let cfg = DraftConfig::new(4);
        let batch = spec_greedy_batch(&m, &[&a, &b], &cfg).unwrap();
        let sa = spec_greedy(&m, &a, &cfg).unwrap();
        let sb = spec_greedy(&m, &b, &cfg).unwrap();
        assert_eq!(batch[0].hyps[0].tokens, sa.hyps[0].tokens);
        assert_eq!(batch[1].hyps[0].tokens, sb.hyps[0].tokens);
    }

    #[test]
    fn scores_match_greedy_scores() {
        let m = HashModel::new(64, 64, 32, 3);
        let mut rng = Rng::new(9);
        let src = random_wrapped_src(&mut rng, 8, 16, 32);
        let g = greedy(&m, &src).unwrap();
        let s = spec_greedy(&m, &src, &DraftConfig::new(5)).unwrap();
        assert!(
            (g.hyps[0].score - s.hyps[0].score).abs() < 1e-5,
            "{} vs {}",
            g.hyps[0].score,
            s.hyps[0].score
        );
    }
}
