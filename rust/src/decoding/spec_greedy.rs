//! Speculative greedy decoding (§2.1, Figure 2).
//!
//! At every step, every draft is concatenated to the current prefix and the
//! whole set is verified in **one** decoder forward pass (drafts inflate the
//! effective batch). The draft with the longest accepted prefix wins; its
//! accepted tokens plus one fresh argmax token are emitted, so each call
//! advances the sequence by 1..=DL+1 tokens. The produced sequence is
//! token-exact equal to standard greedy decoding — speculative decoding
//! "does not affect the content of the predicted sequence in any way".

use std::time::Instant;

use anyhow::Result;

use crate::draft::{extract_drafts, DraftConfig};
use crate::vocab::{BOS_ID, EOS_ID};

use super::{clip_draft, Backend, DecodeOutput, DecodeStats, DecoderRow, Hypothesis};

/// Speculatively greedy-decode one query (batch size 1).
pub fn spec_greedy<B: Backend>(
    backend: &B,
    src: &[i64],
    cfg: &DraftConfig,
) -> Result<DecodeOutput> {
    let mut out = spec_greedy_batch(backend, &[src], cfg)?;
    Ok(out.pop().unwrap())
}

/// Speculative greedy decoding over a batch of queries.
///
/// Every live query contributes `|drafts|` rows per call, so the effective
/// batch is `Σ_live |drafts_i|` — the §3.3 "effective batch inflation".
/// The number of calls is set by the least-lucky sequence: rows for
/// finished queries are dropped, but a call happens while any query lives.
pub fn spec_greedy_batch<B: Backend>(
    backend: &B,
    srcs: &[&[i64]],
    cfg: &DraftConfig,
) -> Result<Vec<DecodeOutput>> {
    let t0 = Instant::now();
    let dims = backend.dims();
    let memory = backend.encode(srcs)?;
    let mut stats = DecodeStats {
        encoder_calls: 1,
        ..Default::default()
    };

    let n = srcs.len();
    // Drafts come from the query *without* its BOS/EOS wrapping.
    let drafts: Vec<Vec<Vec<i64>>> = srcs
        .iter()
        .map(|s| {
            let inner: Vec<i64> = s
                .iter()
                .copied()
                .filter(|&t| t != BOS_ID && t != EOS_ID)
                .collect();
            extract_drafts(&inner, cfg)
        })
        .collect();

    let mut prefixes: Vec<Vec<i64>> = vec![vec![BOS_ID]; n];
    let mut scores = vec![0f64; n];
    let mut done = vec![false; n];
    let mut accepted_total = vec![0usize; n];

    while !done.iter().all(|&d| d) {
        // Assemble rows: prefix ‖ draft for every draft of every live query.
        let mut rows: Vec<DecoderRow> = Vec::new();
        // (query, draft_clipped_len) per row, for result mapping.
        let mut row_meta: Vec<(usize, usize)> = Vec::new();
        for q in 0..n {
            if done[q] {
                continue;
            }
            for d in &drafts[q] {
                let clipped = clip_draft(d, prefixes[q].len(), dims.t_len);
                let mut tokens = prefixes[q].clone();
                tokens.extend_from_slice(clipped);
                rows.push(DecoderRow {
                    tokens,
                    mem_row: q,
                });
                row_meta.push((q, clipped.len()));
            }
        }
        if rows.is_empty() {
            break;
        }
        let lp = backend.decode(&rows, &memory)?;
        stats.decoder_calls += 1;
        stats.decoder_rows += rows.len();

        // For each live query pick the row with the most accepted tokens.
        let mut best: Vec<Option<(usize, usize)>> = vec![None; n]; // (row, k)
        for (r, &(q, dlen)) in row_meta.iter().enumerate() {
            let p = prefixes[q].len();
            let mut k = 0usize;
            while k < dlen {
                let predicted = lp.argmax(r, p - 1 + k);
                if predicted != rows[r].tokens[p + k] {
                    break;
                }
                k += 1;
            }
            match best[q] {
                Some((_, bk)) if bk >= k => {}
                _ => best[q] = Some((r, k)),
            }
        }

        for q in 0..n {
            let Some((r, k)) = best[q] else { continue };
            let p = prefixes[q].len();
            // Emit the k accepted draft tokens, then the fresh argmax after
            // them. Stop early if EOS shows up anywhere in the run.
            let mut emitted: Vec<i64> = rows[r].tokens[p..p + k].to_vec();
            let fresh = lp.argmax(r, p - 1 + k);
            emitted.push(fresh);
            let mut n_accepted = 0usize;
            for (idx, &tok) in emitted.iter().enumerate() {
                scores[q] += lp.logp(r, p - 1 + idx, tok) as f64;
                prefixes[q].push(tok);
                stats.acceptance.total_tokens += 1;
                if tok == EOS_ID {
                    done[q] = true;
                    break;
                }
                if idx < k {
                    n_accepted += 1;
                    stats.acceptance.accepted_draft_tokens += 1;
                }
                if prefixes[q].len() >= dims.t_len {
                    done[q] = true;
                    break;
                }
            }
            accepted_total[q] += n_accepted;
        }
    }

    let wall = t0.elapsed();
    Ok((0..n)
        .map(|q| {
            let mut tokens: Vec<i64> = prefixes[q][1..].to_vec();
            if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
                tokens.truncate(pos);
            }
            let mut s = DecodeStats {
                wall: wall / n as u32,
                ..stats
            };
            s.acceptance.total_tokens = tokens.len() + 1; // incl. EOS step
            s.acceptance.accepted_draft_tokens = accepted_total[q];
            DecodeOutput {
                hyps: vec![Hypothesis {
                    tokens,
                    score: scores[q],
                }],
                stats: s,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::greedy;
    use crate::testutil::{random_wrapped_src, CopyModel, HashModel};
    use crate::rng::Rng;

    /// THE core invariant (paper §2.1): speculative decoding does not
    /// change the produced sequence in any way.
    #[test]
    fn prop_spec_greedy_token_exact_vs_greedy_hash_model() {
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..30 {
            let m = HashModel::new(64, 64, 32, case);
            let src = random_wrapped_src(&mut rng, 4, 20, 32);
            let g = greedy(&m, &src).unwrap();
            for dl in [0usize, 2, 4, 10] {
                let s = spec_greedy(&m, &src, &DraftConfig::new(dl)).unwrap();
                assert_eq!(
                    s.hyps[0].tokens, g.hyps[0].tokens,
                    "case {case} dl {dl}: speculative output diverged"
                );
                assert!(
                    s.stats.decoder_calls <= g.stats.decoder_calls,
                    "speculative used more calls than greedy"
                );
            }
        }
    }

    #[test]
    fn copy_model_accepts_most_draft_tokens() {
        // CopyModel's target literally contains source substrings, so with
        // reasonable DL the acceptance rate should be high and calls should
        // drop well below the token count.
        let m = CopyModel::new(96, 96, 40);
        let src = vec![
            BOS_ID, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23, EOS_ID,
        ];
        let g = greedy(&m, &src).unwrap();
        let s = spec_greedy(&m, &src, &DraftConfig::new(6)).unwrap();
        assert_eq!(s.hyps[0].tokens, g.hyps[0].tokens);
        assert!(
            s.stats.decoder_calls * 2 <= g.stats.decoder_calls,
            "expected ≥2x fewer calls: {} vs {}",
            s.stats.decoder_calls,
            g.stats.decoder_calls
        );
        assert!(s.stats.acceptance.rate() > 0.5, "rate {}", s.stats.acceptance.rate());
    }

    #[test]
    fn dl_zero_is_plain_greedy_in_calls_and_tokens() {
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, EOS_ID];
        let g = greedy(&m, &src).unwrap();
        let s = spec_greedy(&m, &src, &DraftConfig::new(0)).unwrap();
        assert_eq!(s.hyps[0].tokens, g.hyps[0].tokens);
        assert_eq!(s.stats.decoder_calls, g.stats.decoder_calls);
        assert_eq!(s.stats.acceptance.accepted_draft_tokens, 0);
    }

    #[test]
    fn batch_spec_matches_singles() {
        let m = HashModel::new(64, 64, 32, 7);
        let mut rng = Rng::new(5);
        let a = random_wrapped_src(&mut rng, 6, 18, 32);
        let b = random_wrapped_src(&mut rng, 6, 18, 32);
        let cfg = DraftConfig::new(4);
        let batch = spec_greedy_batch(&m, &[&a, &b], &cfg).unwrap();
        let sa = spec_greedy(&m, &a, &cfg).unwrap();
        let sb = spec_greedy(&m, &b, &cfg).unwrap();
        assert_eq!(batch[0].hyps[0].tokens, sa.hyps[0].tokens);
        assert_eq!(batch[1].hyps[0].tokens, sb.hyps[0].tokens);
    }

    #[test]
    fn scores_match_greedy_scores() {
        let m = HashModel::new(64, 64, 32, 3);
        let mut rng = Rng::new(9);
        let src = random_wrapped_src(&mut rng, 8, 16, 32);
        let g = greedy(&m, &src).unwrap();
        let s = spec_greedy(&m, &src, &DraftConfig::new(5)).unwrap();
        assert!(
            (g.hyps[0].score - s.hyps[0].score).abs() < 1e-5,
            "{} vs {}",
            g.hyps[0].score,
            s.hyps[0].score
        );
    }
}
