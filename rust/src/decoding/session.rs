//! The stateless-recompute [`DecoderSession`] adapter.
//!
//! Wraps any [`Backend`] behind the session interface by keeping plain
//! per-row token buffers and re-submitting full prefixes through
//! [`Backend::decode`] on every `extend`. This is the compatibility
//! bridge: the mock backends in `testutil`, and any backend without a
//! cache-aware session — for the PJRT path that now means only artifact
//! sets *without* `deccache` rows (or runs forced via
//! `RXNSPEC_NO_DECCACHE`) — all decode through it, with exactly the
//! pre-session behaviour and cost (`tokens_reused` stays 0).
//!
//! It is also the oracle in the session-parity property tests: because a
//! conditionally-consistent backend's distributions depend only on each
//! row's own prefix, a cached session must produce bit-identical
//! log-probabilities to this adapter.

use anyhow::Result;

use super::{Backend, DecoderRow, DecoderSession, LogProbs, Memory, ModelDims, SessionStats};

/// Default per-row log-prob retention (positions) when `RXNSPEC_LP_RETAIN`
/// is unset — comfortably above any draft window the decoders submit.
/// Shared by every cache-aware session (the reference transformer's and
/// the PJRT deccache session), so the two cannot drift apart.
pub(crate) const DEFAULT_LP_RETAIN: usize = 64;

/// The `RXNSPEC_LP_RETAIN` knob, parsed once per session: how many
/// positions of per-row successor log-probs to retain (min 1; deeper
/// rewinds are healed by one exact recompute).
pub(crate) fn lp_retention_from_env() -> usize {
    crate::knobs::LP_RETAIN.parsed_or(DEFAULT_LP_RETAIN).max(1)
}

// ---------------------------------------------------------------------------
// Shared cache-aware-session mechanics
//
// The reference transformer's `CachedSession` and the PJRT
// `CachedPjrtSession` keep different cache representations (KvPanels vs
// flat device mirrors) but implement the *same* session contract. The
// contract-critical arithmetic lives here once, so the two cannot drift:
// the deep-rewind heal + log-prob rollback, the result-window sizing,
// the windowed-LogProbs assembly, and the retention drain.
// ---------------------------------------------------------------------------

/// Roll one row's token history and retained log-prob suffix back to the
/// extend submit point. A rewind past the retained suffix is healed by
/// prepending the last committed token to the job (its recompute against
/// the cached K/V prefix is exact). `kv_valid` is how many positions of
/// the row's K/V are still resident: always `len_before` for dense
/// caches, less after a paged arena evicted the row — the resume point
/// drops to `min(kv_valid, lp-rule start)` and every position from there
/// to `len_before` is prepended to the job, so the rehydration recompute
/// is exact by the kernels' bit-exactness contract and eviction can
/// never change a logit. Returns `(start, job_tokens)`: `start` is the
/// committed length the backend resumes from, and `job_tokens` the
/// window to compute (callers append it to `tokens` when their compute
/// step doesn't).
pub(crate) fn rollback_for_extend_kv<'t>(
    tokens: &mut Vec<i64>,
    lp: &mut Vec<f32>,
    lp_start: &mut usize,
    len_before: usize,
    kv_valid: usize,
    toks: &'t [i64],
    vocab: usize,
) -> (usize, std::borrow::Cow<'t, [i64]>) {
    // The log-prob rule: serving the window needs the successor
    // distribution of position len_before - 1, so a rewind past the
    // retained suffix heals by recomputing that one position.
    let lp_rule_start = if len_before > 0 && len_before - 1 < *lp_start {
        len_before - 1
    } else {
        len_before
    };
    let start = lp_rule_start.min(kv_valid.min(len_before));
    let job = if start == len_before {
        std::borrow::Cow::Borrowed(toks)
    } else {
        let mut jt = Vec::with_capacity(len_before - start + toks.len());
        jt.extend_from_slice(&tokens[start..len_before]);
        jt.extend_from_slice(toks);
        std::borrow::Cow::Owned(jt)
    };
    tokens.truncate(start);
    if start <= *lp_start {
        lp.clear();
        *lp_start = start;
    } else {
        lp.truncate((start - *lp_start) * vocab);
    }
    (start, job)
}

/// Stored-window columns one row needs from an extend's result: the
/// successor distributions of the last pre-extend token and of every
/// appended token (the `DecoderSession::extend` contract).
pub(crate) fn needed_window(len_before: usize, delta_len: usize) -> usize {
    (delta_len + usize::from(len_before > 0)).min(len_before + delta_len)
}

/// Copy one row's readable log-prob columns into the shared windowed
/// result buffer (`[rows, window, vocab]`, rows right-aligned). Columns
/// before the retained suffix are unreadable by contract and stay zero.
pub(crate) fn assemble_window_row(
    data: &mut [f32],
    ri: usize,
    window: usize,
    vocab: usize,
    len: usize,
    lp: &[f32],
    lp_start: usize,
) {
    let lo = len.saturating_sub(window).max(lp_start);
    for j in lo..len {
        let wcol = window - len + j;
        let dst = (ri * window + wcol) * vocab;
        let src = (j - lp_start) * vocab;
        data[dst..dst + vocab].copy_from_slice(&lp[src..src + vocab]);
    }
}

/// Drain a row's log-prob suffix down to `retain` positions, advancing
/// `lp_start`. Returns the pre-trim retained count (the
/// `lp_high_water` sample).
pub(crate) fn trim_lp_suffix(
    lp: &mut Vec<f32>,
    lp_start: &mut usize,
    vocab: usize,
    retain: usize,
) -> usize {
    let retained = lp.len() / vocab;
    if retained > retain {
        let excess = retained - retain;
        lp.drain(..excess * vocab);
        *lp_start += excess;
    }
    retained
}

struct Row {
    tokens: Vec<i64>,
    mem_row: usize,
}

/// See module docs.
pub struct StatelessSession<'a, B: Backend> {
    backend: &'a B,
    memory: Memory,
    rows: Vec<Option<Row>>,
    stats: SessionStats,
}

impl<'a, B: Backend> StatelessSession<'a, B> {
    pub fn new(backend: &'a B, memory: Memory) -> StatelessSession<'a, B> {
        let batch = memory.batch;
        StatelessSession {
            backend,
            memory,
            rows: Vec::new(),
            // Same encoder accounting as the cached session: the memory
            // came from one encode call over `batch` source rows.
            stats: SessionStats {
                encode_calls: 1,
                packed_src_rows: batch,
                ..SessionStats::default()
            },
        }
    }

    fn row(&self, row: usize) -> &Row {
        self.rows[row].as_ref().expect("released session row")
    }

    fn row_mut(&mut self, row: usize) -> &mut Row {
        self.rows[row].as_mut().expect("released session row")
    }
}

impl<B: Backend> DecoderSession for StatelessSession<'_, B> {
    fn dims(&self) -> ModelDims {
        self.backend.dims()
    }

    fn memory(&self) -> &Memory {
        &self.memory
    }

    fn append_memory(&mut self, extra: &Memory) -> usize {
        assert_eq!(extra.s_len, self.memory.s_len, "memory s_len mismatch");
        assert_eq!(extra.d_model, self.memory.d_model, "memory width mismatch");
        let base = self.memory.batch;
        self.memory.data.extend_from_slice(&extra.data);
        self.memory.pad.extend_from_slice(&extra.pad);
        self.memory.batch += extra.batch;
        self.stats.encode_calls += 1;
        self.stats.packed_src_rows += extra.batch;
        base
    }

    fn new_row(&mut self, mem_row: usize) -> usize {
        assert!(mem_row < self.memory.batch, "memory row out of range");
        self.rows.push(Some(Row {
            tokens: Vec::new(),
            mem_row,
        }));
        self.rows.len() - 1
    }

    fn fork(&mut self, row: usize) -> usize {
        let src = self.row(row);
        let copy = Row {
            tokens: src.tokens.clone(),
            mem_row: src.mem_row,
        };
        self.rows.push(Some(copy));
        self.rows.len() - 1
    }

    fn truncate(&mut self, row: usize, len: usize) {
        let r = self.row_mut(row);
        assert!(len <= r.tokens.len(), "truncate beyond row length");
        r.tokens.truncate(len);
    }

    fn release(&mut self, row: usize) {
        self.rows[row] = None;
    }

    fn row_len(&self, row: usize) -> usize {
        self.row(row).tokens.len()
    }

    fn extend(&mut self, deltas: &[(usize, &[i64])]) -> Result<LogProbs> {
        let t_len = self.backend.dims().t_len;
        let mut call_rows: Vec<DecoderRow> = Vec::with_capacity(deltas.len());
        for &(row, toks) in deltas {
            let r = self.rows[row].as_mut().expect("released session row");
            r.tokens.extend_from_slice(toks);
            assert!(
                r.tokens.len() <= t_len,
                "row length {} exceeds window {t_len}",
                r.tokens.len()
            );
            call_rows.push(DecoderRow {
                tokens: r.tokens.clone(),
                mem_row: r.mem_row,
            });
        }
        self.stats.extend_calls += 1;
        self.stats.packed_rows += deltas.len();
        for cr in &call_rows {
            // Full recompute: every position of every submitted row.
            self.stats.tokens_computed += cr.tokens.len();
        }
        self.backend.decode(&call_rows, &self.memory)
    }

    fn stats(&self) -> SessionStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CopyModel;
    use crate::vocab::{BOS_ID, EOS_ID};

    #[test]
    fn stateless_session_matches_direct_decode() {
        let m = CopyModel::new(32, 32, 20);
        let src: Vec<i64> = vec![BOS_ID, 10, 11, 12, EOS_ID];
        let memory = m.encode(&[&src]).unwrap();
        let direct = m
            .decode(
                &[DecoderRow {
                    tokens: vec![BOS_ID, 10, 11],
                    mem_row: 0,
                }],
                &memory,
            )
            .unwrap();

        let mut sess = m.begin(m.encode(&[&src]).unwrap()).unwrap();
        let r = sess.new_row(0);
        let lp = sess.extend(&[(r, &[BOS_ID, 10, 11])]).unwrap();
        for j in 0..3 {
            for v in 0..20 {
                assert_eq!(direct.logp(0, j, v), lp.logp(0, j, v));
            }
        }
        let s = sess.stats();
        assert_eq!(s.extend_calls, 1);
        assert_eq!(s.tokens_computed, 3);
        assert_eq!(s.tokens_reused, 0);
    }

    #[test]
    fn fork_truncate_release_roundtrip() {
        let m = CopyModel::new(32, 32, 20);
        let src: Vec<i64> = vec![BOS_ID, 10, 11, EOS_ID];
        let mut sess = m.begin(m.encode(&[&src]).unwrap()).unwrap();
        let a = sess.new_row(0);
        sess.extend(&[(a, &[BOS_ID, 10])]).unwrap();
        let b = sess.fork(a);
        assert_eq!(sess.row_len(b), 2);
        sess.extend(&[(b, &[11])]).unwrap();
        assert_eq!(sess.row_len(a), 2, "fork must not touch the parent");
        assert_eq!(sess.row_len(b), 3);
        sess.truncate(b, 1);
        assert_eq!(sess.row_len(b), 1);
        sess.release(a);
        // Released ids stay allocated (never reused); b still works.
        let lp = sess.extend(&[(b, &[10])]).unwrap();
        assert_eq!(lp.n_rows(), 1);
    }

    #[test]
    fn append_memory_offsets_rows() {
        let m = CopyModel::new(32, 32, 20);
        let s1: Vec<i64> = vec![BOS_ID, 10, EOS_ID];
        let s2: Vec<i64> = vec![BOS_ID, 12, 13, EOS_ID];
        let mut sess = m.begin(m.encode(&[&s1]).unwrap()).unwrap();
        let extra = m.encode(&[&s2]).unwrap();
        let base = sess.append_memory(&extra);
        assert_eq!(base, 1);
        assert_eq!(sess.memory().batch, 2);
        let r = sess.new_row(base);
        let lp = sess.extend(&[(r, &[BOS_ID])]).unwrap();
        // CopyModel's first target token for s2 is 12.
        assert_eq!(lp.argmax(0, 0), 12);
    }
}
