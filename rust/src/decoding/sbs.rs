//! Speculative beam search (SBS) — the paper's Algorithm 1 (Appendix B) —
//! on incremental sessions.
//!
//! At every iteration each live beam is concatenated with every draft and
//! the whole ragged batch is verified in one decoder forward pass (rows are
//! right-aligned into the fixed window — the paper's `padLeft` with shifted
//! positional encodings). Per beam, the draft with the longest accepted
//! prefix is selected (`selectBestDraft`); candidate sequences of *unequal
//! lengths* are proposed along that accepted prefix (`sample`: for every
//! accepted length `j`, the top-n successor tokens), ranked by cumulative
//! log-probability (`sortAndExtract`), and the best `n` survive.
//!
//! Session mechanics: each (beam × draft) verify row is a
//! [`fork`](super::DecoderSession::fork) of the beam's committed row
//! extended by `pending ‖ draft`; each surviving candidate forks the row
//! of the draft it was sampled from and
//! [`truncate`](super::DecoderSession::truncate)s it back to its accepted
//! prefix, so the accepted tokens' K/V are *reused*, never recomputed.
//! All other forks are released at the end of the iteration.
//!
//! With a never-accepted draft (DL=0 ⇒ a single BOS draft) the candidate
//! set degenerates to "top-n successors of each beam" — exactly standard
//! beam search. This equivalence is property-tested.

use std::time::Instant;

use crate::trace::Phase;
use crate::trace_span;

use anyhow::Result;

use crate::draft::{extract_drafts_merged, DraftConfig, DraftSource};
use crate::vocab::{BOS_ID, EOS_ID, PAD_ID};

use super::beam::{rank_by, BeamPool, BeamState};
use super::{clip_draft, Backend, DecodeOutput, DecodeStats, Hypothesis};

/// Speculative-beam-search configuration.
#[derive(Debug, Clone)]
pub struct SbsConfig {
    /// Beam width == number of returned hypotheses (the paper keeps them
    /// equal).
    pub n: usize,
    /// Draft extraction parameters.
    pub draft: DraftConfig,
    /// Hard cap on decoder rows per forward pass. The effective batch is
    /// `beams × drafts`; when it would exceed this, the draft list is
    /// truncated — the paper's §3.3 mitigation ("we put a boundary on the
    /// number of drafts ... however, this compromises the acceptance
    /// rate").
    pub max_rows: usize,
    /// Corpus-learned draft windows (`cache::DraftStore::top_k`), merged
    /// *behind* the query-copy windows under the shared `max_drafts` cap.
    /// Never-accepted corpus windows are provably output-neutral (they
    /// lose every best-draft selection and row truncation cuts from the
    /// tail); accepted ones deepen the verified greedy prefix — the same
    /// effect as a longer `DL`.
    pub corpus_drafts: Vec<Vec<i64>>,
}

impl SbsConfig {
    pub fn new(n: usize, draft_len: usize) -> Self {
        SbsConfig {
            n,
            draft: DraftConfig::new(draft_len),
            max_rows: 256,
            corpus_drafts: Vec::new(),
        }
    }
}

/// Per-iteration trace record (drives the Figure 3 walk-through).
#[derive(Debug, Clone)]
pub struct SbsIterTrace {
    /// Candidate sequences proposed this iteration (before top-n cut).
    pub candidates_generated: usize,
    /// Decoder rows submitted this iteration (beams × drafts).
    pub rows: usize,
    /// The kept beams: (generated tokens so far, score).
    pub kept: Vec<(Vec<i64>, f64)>,
}

/// Full trace of one SBS run.
#[derive(Debug, Clone, Default)]
pub struct SbsTrace {
    pub iterations: Vec<SbsIterTrace>,
}

/// Speculative beam search. See module docs.
pub fn sbs<B: Backend>(backend: &B, src: &[i64], cfg: &SbsConfig) -> Result<DecodeOutput> {
    sbs_impl(backend, src, cfg, None).map(|(out, _)| out)
}

/// SBS with a per-iteration trace (used by `examples/retro_planning
/// --trace` to regenerate the paper's Figure 3 walk-through).
pub fn sbs_traced<B: Backend>(
    backend: &B,
    src: &[i64],
    cfg: &SbsConfig,
) -> Result<(DecodeOutput, SbsTrace)> {
    let mut trace = SbsTrace::default();
    let (out, _) = sbs_impl(backend, src, cfg, Some(&mut trace))?;
    Ok((out, trace))
}

/// A live beam: its search state plus session bookkeeping.
struct Live {
    state: BeamState,
    /// Committed session row (length `sess_len`); the trailing token of
    /// `state.tokens` is still pending.
    row: usize,
    sess_len: usize,
}

/// A proposed candidate: search state plus where its verified prefix
/// lives (`from_row` up to `keep_len` committed positions) and which
/// draft source its accepted prefix came from.
struct Cand {
    state: BeamState,
    from_row: usize,
    keep_len: usize,
    src: DraftSource,
}

fn sbs_impl<B: Backend>(
    backend: &B,
    src: &[i64],
    cfg: &SbsConfig,
    mut trace: Option<&mut SbsTrace>,
) -> Result<(DecodeOutput, ())> {
    let t0 = Instant::now();
    // `trace` is the algorithm-trace parameter; the span layer is
    // addressed by full path to keep the two apart.
    let ph0 = crate::trace::thread_phase_ns();
    let dims = backend.dims();
    let memory = {
        let _enc = trace_span!(Phase::Encode, 1);
        backend.encode(&[src])?
    };
    let mut sess = {
        let _beg = trace_span!(Phase::SessionBegin);
        backend.begin(memory)?
    };
    let mut stats = DecodeStats {
        encoder_calls: 1,
        ..Default::default()
    };

    // getDrafts: windows of the unwrapped query, then corpus-learned
    // windows behind them (shared dedup set, shared max_drafts cap).
    let inner: Vec<i64> = src
        .iter()
        .copied()
        .filter(|&t| t != BOS_ID && t != EOS_ID)
        .collect();
    let mut drafts = extract_drafts_merged(&inner, &cfg.draft, &cfg.corpus_drafts);

    let root = sess.new_row(0);
    let mut beams = vec![Live {
        state: BeamState {
            tokens: vec![BOS_ID],
            score: 0.0,
        },
        row: root,
        sess_len: 0,
    }];
    let mut pool = BeamPool::new(cfg.n);

    while !beams.is_empty() {
        // Bound the effective batch: beams × drafts ≤ max_rows.
        let max_drafts = (cfg.max_rows / beams.len()).max(1);
        if drafts.len() > max_drafts {
            drafts.truncate(max_drafts);
        }

        // concatDraftsToSequences: one fork of the beam's committed row
        // per draft, extended by the pending suffix plus the draft.
        let mut frows: Vec<usize> = Vec::new();
        let mut delta_buf: Vec<Vec<i64>> = Vec::new();
        let mut row_meta: Vec<(usize, usize, usize)> = Vec::new(); // (beam, draft, clipped_len)
        {
            let _fk = trace_span!(Phase::Fork, (beams.len() * drafts.len()) as u64);
            for (bi, b) in beams.iter().enumerate() {
                for (di, d) in drafts.iter().enumerate() {
                    let clipped = clip_draft(&d.tokens, b.state.tokens.len(), dims.t_len);
                    let mut delta = b.state.tokens[b.sess_len..].to_vec();
                    delta.extend_from_slice(clipped);
                    let clen = clipped.len();
                    frows.push(sess.fork(b.row));
                    delta_buf.push(delta);
                    row_meta.push((bi, di, clen));
                }
            }
        }
        let deltas: Vec<(usize, &[i64])> = frows
            .iter()
            .zip(&delta_buf)
            .map(|(&r, d)| (r, d.as_slice()))
            .collect();
        crate::faults::fire("decoder.extend")?;
        let lp = {
            let _ext = trace_span!(Phase::Extend, deltas.len() as u64);
            sess.extend(&deltas)?
        };
        stats.decoder_calls += 1;
        stats.decoder_rows += deltas.len();
        let n_rows_iter = deltas.len();
        drop(deltas);

        // selectBestDraft per beam: most accepted tokens, ties → first.
        let mut best: Vec<Option<(usize, usize)>> = vec![None; beams.len()];
        {
            let _vf = trace_span!(Phase::Verify, n_rows_iter as u64);
            for (r, &(bi, di, clen)) in row_meta.iter().enumerate() {
                let p = beams[bi].state.tokens.len();
                let draft = &drafts[di].tokens;
                let mut k = 0usize;
                while k < clen {
                    let d_tok = draft[k];
                    if d_tok == EOS_ID || d_tok == BOS_ID || d_tok == PAD_ID {
                        break;
                    }
                    if lp.argmax(r, p - 1 + k) != d_tok {
                        break;
                    }
                    k += 1;
                }
                match best[bi] {
                    Some((_, bk)) if bk >= k => {}
                    _ => best[bi] = Some((r, k)),
                }
            }
        }

        // sample: candidates of unequal lengths along the accepted prefix
        // — for every accepted length j (0..=k), the top-n successor
        // tokens, scored by their true cumulative log-probability. The
        // paper's Figure 3: `(k+1) · n` candidates per beam.
        let mut candidates: Vec<Cand> = Vec::new();
        for (bi, b) in beams.iter().enumerate() {
            let (r, k) = best[bi].unwrap();
            let di = row_meta[r].1;
            let win_source = drafts[di].source;
            let draft = &drafts[di].tokens;
            let p = b.state.tokens.len();
            let mut draft_prefix_logp = 0f64;
            for j in 0..=k {
                let d_next = (j < k).then(|| draft[j]);
                for (tok, logp) in lp.topk(r, p - 1 + j, cfg.n) {
                    if tok == BOS_ID || tok == PAD_ID {
                        continue;
                    }
                    // One candidate per *path*: stopping exactly on the
                    // accepted draft token duplicates the longer candidate
                    // that continues along it. Keeping such nested
                    // prefixes would crowd the beam with copies of one
                    // path and starve the diverse deviations standard
                    // beam search maintains. (Figure 3's kept candidates
                    // are likewise one-per-path, unequal lengths.)
                    if Some(tok) == d_next {
                        continue;
                    }
                    let mut tokens = b.state.tokens.clone();
                    tokens.extend_from_slice(&draft[..j]);
                    tokens.push(tok);
                    candidates.push(Cand {
                        state: BeamState {
                            tokens,
                            score: b.state.score + draft_prefix_logp + logp as f64,
                        },
                        from_row: frows[r],
                        keep_len: p + j,
                        src: win_source,
                    });
                }
                if let Some(d_tok) = d_next {
                    draft_prefix_logp += lp.logp(r, p - 1 + j, d_tok) as f64;
                }
            }
        }
        let n_generated = candidates.len();

        // Candidates of unequal lengths can collide (beam "ab" + draft "c"
        // equals beam "abc" extended directly); identical sequences have
        // identical scores by conditional consistency — keep one. Ranking
        // is the shared length-normalized order (see `rank_by`).
        rank_by(&mut candidates, |c| &c.state);
        candidates.dedup_by(|a, b| a.state.tokens == b.state.tokens);

        // sortAndExtract + retire finished.
        //
        // Diversity cap: length-normalized ranking systematically favours
        // candidates with long accepted prefixes, so without a cap the
        // beam fills with several variants of ONE parent's draft path and
        // starves the early deviations standard beam search keeps (e.g.
        // the equal-probability reactant-order permutation). At most
        // ⌈n/2⌉ survivors per parent beam in the first pass; remaining
        // slots fill rank-order in a second pass.
        let per_parent_cap = cfg.n.div_ceil(2);
        let mut kept: Vec<&Cand> = Vec::with_capacity(cfg.n);
        let mut kept_idx: Vec<usize> = Vec::new();
        let mut parent_count = vec![0usize; beams.len()];
        let parent_of = |tokens: &[i64]| -> usize {
            // Candidates extend their parent's tokens; identify by prefix.
            beams
                .iter()
                .enumerate()
                .filter(|(_, b)| {
                    tokens.len() > b.state.tokens.len()
                        && tokens[..b.state.tokens.len()] == b.state.tokens[..]
                })
                .map(|(i, b)| (i, b.state.tokens.len()))
                .max_by_key(|&(_, l)| l)
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        for (ci_idx, c) in candidates.iter().enumerate() {
            if kept.len() >= cfg.n {
                break;
            }
            let p_idx = parent_of(&c.state.tokens);
            // One-token extensions are exactly standard beam search's
            // candidates: they always compete freely (this also keeps
            // SBS(DL=0) ≡ BS exact). Only the *speculative* multi-token
            // candidates are capped per parent.
            let bs_like = c.state.tokens.len() == beams[p_idx].state.tokens.len() + 1;
            if !bs_like && parent_count[p_idx] >= per_parent_cap {
                continue;
            }
            if !bs_like {
                parent_count[p_idx] += 1;
            }
            kept_idx.push(ci_idx);
            kept.push(c);
        }
        // Fill pass: rank order, ignoring the cap.
        if kept.len() < cfg.n {
            for (ci_idx, c) in candidates.iter().enumerate() {
                if kept.len() >= cfg.n {
                    break;
                }
                if !kept_idx.contains(&ci_idx) {
                    kept_idx.push(ci_idx);
                    kept.push(c);
                }
            }
        }
        // Re-rank the kept set and process retire/keep decisions in order.
        let mut kept: Vec<Cand> = kept
            .into_iter()
            .map(|c| Cand {
                state: c.state.clone(),
                from_row: c.from_row,
                keep_len: c.keep_len,
                src: c.src,
            })
            .collect();
        rank_by(&mut kept, |c| &c.state);
        let candidates = kept;
        let mut kept: Vec<Cand> = Vec::with_capacity(cfg.n);
        let prev_top_len = beams[0].state.tokens.len();
        for c in candidates {
            if kept.len() >= cfg.n {
                break;
            }
            let t = &c.state.tokens;
            let gen_len = t.len() - 1;
            if *t.last().unwrap() == EOS_ID {
                // A surviving prefix beam can re-derive an extension that
                // already finished on an earlier iteration; such repeats
                // must not consume hypothesis slots again.
                if pool.contains(&t[..t.len() - 1]) {
                    continue;
                }
                pool.push_finished(&t[..t.len() - 1], c.state.score, gen_len);
                // finished hypotheses also occupy candidate slots, exactly
                // as in `beam_search`.
                kept.push(c);
            } else if t.len() >= dims.t_len {
                pool.push_finished(t, c.state.score, gen_len);
                kept.push(c);
            } else {
                kept.push(c);
            }
        }
        // Acceptance accounting on the top kept candidate: its length
        // growth beyond 1 is accepted draft copy.
        if let Some(top) = kept.first() {
            let grew = top.state.tokens.len().saturating_sub(prev_top_len);
            stats.acceptance.total_tokens += grew;
            let accepted = grew.saturating_sub(1);
            stats.acceptance.accepted_draft_tokens += accepted;
            match top.src {
                DraftSource::QueryCopy => stats.accepted_query_tokens += accepted,
                DraftSource::Corpus => stats.accepted_corpus_tokens += accepted,
                DraftSource::Sentinel => {}
            }
        }

        if let Some(tr) = trace.as_deref_mut() {
            tr.iterations.push(SbsIterTrace {
                candidates_generated: n_generated,
                rows: n_rows_iter,
                kept: kept
                    .iter()
                    .map(|c| (c.state.tokens[1..].to_vec(), c.state.score))
                    .collect(),
            });
        }

        // Build the next generation of live beams: fork the verified
        // prefix out of the winning verify row, roll back the rejected
        // tail, and leave the candidate's fresh token pending.
        let mut next: Vec<Live> = Vec::new();
        {
            let _tr = trace_span!(Phase::Truncate, kept.len() as u64);
            for c in kept {
                let t = &c.state.tokens;
                if *t.last().unwrap() == EOS_ID || t.len() >= dims.t_len {
                    continue; // retired above
                }
                let row = sess.fork(c.from_row);
                sess.truncate(row, c.keep_len);
                next.push(Live {
                    sess_len: c.keep_len,
                    row,
                    state: c.state,
                });
            }
        }

        // Verify forks and superseded parent rows are done.
        for &r in &frows {
            sess.release(r);
        }
        for b in &beams {
            sess.release(b.row);
        }

        beams = next;
        let best_live_norm = beams
            .first()
            .map(|b| b.state.norm())
            .unwrap_or(f64::NEG_INFINITY);
        if pool.can_stop(best_live_norm) {
            break;
        }
    }

    stats.absorb_session(&sess.stats());
    stats.wall = t0.elapsed();
    let ph1 = crate::trace::thread_phase_ns();
    let phase_us = |p: Phase| ph1[p as usize].saturating_sub(ph0[p as usize]) / 1000;
    stats.encode_us = phase_us(Phase::Encode);
    stats.extend_us = phase_us(Phase::Extend);
    stats.verify_us = phase_us(Phase::Verify);
    Ok((
        DecodeOutput {
            hyps: pool.sorted(),
            stats,
        },
        (),
    ))
}

/// Convenience: build the hypotheses' SMILES strings.
pub fn hyps_to_smiles(vocab: &crate::vocab::Vocab, hyps: &[Hypothesis]) -> Vec<String> {
    hyps.iter().map(|h| vocab.decode(&h.tokens)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam_search;
    use crate::rng::Rng;
    use crate::testutil::{random_wrapped_src, CopyModel, HashModel};

    /// DL=0 ⇒ SBS must equal standard beam search exactly (paper §3.2:
    /// "SBS reduces to the standard beam search when draft tokens are
    /// never accepted").
    #[test]
    fn prop_sbs_dl0_equals_beam_search() {
        let mut rng = Rng::new(0xBEEF);
        for case in 0..15 {
            let m = HashModel::new(64, 64, 32, case + 500);
            let src = random_wrapped_src(&mut rng, 5, 18, 32);
            for n in [1usize, 3, 5] {
                let bs = beam_search(&m, &src, n).unwrap();
                let sb = sbs(&m, &src, &SbsConfig::new(n, 0)).unwrap();
                assert_eq!(bs.hyps.len(), sb.hyps.len(), "case {case} n {n}");
                for (a, b) in bs.hyps.iter().zip(&sb.hyps) {
                    assert_eq!(a.tokens, b.tokens, "case {case} n {n}");
                    assert!((a.score - b.score).abs() < 1e-5);
                }
            }
        }
    }

    /// Statistical version of the paper's Table 4 claim. Exact per-query
    /// equality between BS and SBS is a property of genuinely low-entropy
    /// trained models (the real check runs against the trained artifact in
    /// the Table 4 bench); on the semi-peaked hash mock — where draft
    /// acceptances are accidental rather than structural — we demand high
    /// but not perfect agreement. Measured baseline: 40/50 top-1, 229/250
    /// set agreement.
    #[test]
    fn prop_sbs_with_drafts_mostly_matches_beam_search() {
        let mut rng = Rng::new(0xF00D);
        let (mut top1, mut agreements, mut total) = (0usize, 0usize, 0usize);
        let n_cases = 50usize;
        for case in 0..n_cases {
            let m = HashModel::peaked(64, 64, 32, case as u64 + 900);
            let src = random_wrapped_src(&mut rng, 6, 20, 32);
            let n = 5;
            let bs = beam_search(&m, &src, n).unwrap();
            let sb = sbs(&m, &src, &SbsConfig::new(n, 6)).unwrap();
            if bs.hyps[0].tokens == sb.hyps[0].tokens {
                top1 += 1;
            }
            for h in &sb.hyps {
                total += 1;
                if bs.hyps.iter().any(|g| g.tokens == h.tokens) {
                    agreements += 1;
                }
            }
        }
        // Sanity floor on the synthetic mock (accidental acceptances push
        // the two searches onto different frontiers); the real Table 4
        // check — accuracy equality on the trained model — lives in
        // rust/tests/serving_e2e.rs and the table3 bench.
        assert!(top1 * 100 >= n_cases * 50, "top-1 agreement {top1}/{n_cases}");
        assert!(
            agreements * 100 >= total * 60,
            "only {agreements}/{total} hypotheses agree"
        );
    }

    /// Universal invariant, any entropy regime: every hypothesis either
    /// algorithm returns carries its *true* cumulative model log-prob.
    #[test]
    fn prop_returned_scores_are_true_model_scores() {
        let mut rng = Rng::new(0xABBA);
        for case in 0..10 {
            let m = HashModel::new(64, 64, 32, case + 40);
            let src = random_wrapped_src(&mut rng, 6, 18, 32);
            let bs = beam_search(&m, &src, 4).unwrap();
            let sb = sbs(&m, &src, &SbsConfig::new(4, 5)).unwrap();
            for out in [&bs, &sb] {
                for h in &out.hyps {
                    let truth = crate::testutil::rescore(&m, &src, &h.tokens, true);
                    assert!(
                        (truth - h.score).abs() < 1e-4,
                        "case {case}: reported {} true {truth} for {:?}",
                        h.score,
                        h.tokens
                    );
                }
            }
        }
    }

    #[test]
    fn sbs_uses_fewer_calls_on_copy_model() {
        let m = CopyModel::new(96, 96, 40);
        let src = vec![
            BOS_ID, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, EOS_ID,
        ];
        let bs = beam_search(&m, &src, 3).unwrap();
        let sb = sbs(&m, &src, &SbsConfig::new(3, 8)).unwrap();
        assert_eq!(bs.hyps[0].tokens, sb.hyps[0].tokens);
        assert!(
            sb.stats.decoder_calls < bs.stats.decoder_calls,
            "SBS {} calls vs BS {}",
            sb.stats.decoder_calls,
            bs.stats.decoder_calls
        );
    }

    #[test]
    fn trace_counts_candidates() {
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, EOS_ID];
        let (out, trace) = sbs_traced(&m, &src, &SbsConfig::new(2, 10)).unwrap();
        assert!(!out.hyps.is_empty());
        assert!(!trace.iterations.is_empty());
        // First iteration: 1 beam × up to n·(k+1) candidates.
        assert!(trace.iterations[0].candidates_generated >= 2);
        assert!(trace.iterations[0].rows >= 1);
    }

    #[test]
    fn max_rows_cap_respected() {
        let m = HashModel::new(64, 64, 32, 77);
        let mut rng = Rng::new(123);
        let src = random_wrapped_src(&mut rng, 10, 24, 32);
        let mut cfg = SbsConfig::new(5, 4);
        cfg.max_rows = 10;
        let (_, trace) = sbs_traced(&m, &src, &cfg).unwrap();
        for it in &trace.iterations {
            assert!(it.rows <= 10, "rows {} exceed cap", it.rows);
        }
    }
}
