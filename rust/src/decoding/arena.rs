//! Page-pooled KV arena: the residency layer behind both cache-aware
//! sessions.
//!
//! Dense cached sessions own one K/V buffer per row sized for the full
//! decoder window, and `fork()` Arc-shares it only until the first
//! divergent write — at which point the whole row is deep-copied. Under
//! fork-heavy beam/SBS serving that is O(rows × t_len) memory and
//! O(t_len) bytes copied per divergence. The arena replaces row
//! ownership with **page tables**: K/V lives in fixed-size pages
//! (`RXNSPEC_KV_PAGE` positions each, default 16) pooled in one slab,
//! rows hold `Vec<page id>` tables, and pages are refcounted so
//!
//! * `fork()` clones the page table and bumps refcounts — O(pages)
//!   pointer work, zero float traffic;
//! * the first divergent write copy-on-writes only the shared partial
//!   tail page (one page, not the row);
//! * `truncate()` returns whole pages past the cut to the free list;
//! * a soft memory budget (`RXNSPEC_KV_BUDGET`) triggers LRU eviction of
//!   cold rows' pages — evicted rows stay valid and are *rehydrated* by
//!   the sessions' deep-rewind heal (an exact recompute, so eviction can
//!   never change a logit).
//!
//! The arena stores opaque f32 blobs: each page holds `page_positions ×
//! pos_floats` floats for K and the same for V, where `pos_floats` is
//! whatever one position costs the owning session across all layers
//! (`n_layers × d_model` for both current sessions). The *layout inside
//! a page* is the session's contract with its attention/upload code —
//! the arena only manages residency, sharing, and reuse.
//!
//! `RXNSPEC_ARENA=off` disables the arena ([`ArenaConfig::from_env`]
//! returns `None`) and sessions fall back to the dense per-row path,
//! which doubles as the parity oracle for the paged one.

use crate::trace::Phase;
use crate::trace_span;

/// Default page size in positions when `RXNSPEC_KV_PAGE` is unset.
pub const DEFAULT_PAGE_POSITIONS: usize = 16;

/// Arena sizing knobs, resolved from the environment once per session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaConfig {
    /// Positions per page (min 1).
    pub page_positions: usize,
    /// Soft K/V residency budget in bytes; `None` = unbounded. Crossing
    /// the budget evicts cold unpinned rows, but allocation proceeds
    /// even when nothing is evictable (the budget sheds cold state, it
    /// does not fail hot requests).
    pub budget_bytes: Option<usize>,
}

impl Default for ArenaConfig {
    fn default() -> ArenaConfig {
        ArenaConfig {
            page_positions: DEFAULT_PAGE_POSITIONS,
            budget_bytes: None,
        }
    }
}

impl ArenaConfig {
    /// Resolve the arena knobs: `RXNSPEC_ARENA` set to `off` / `0` /
    /// `false` / `dense` disables the arena entirely (dense fallback);
    /// otherwise `RXNSPEC_KV_PAGE` sets the page size in positions and
    /// `RXNSPEC_KV_BUDGET` the soft byte budget (plain bytes, or with a
    /// `k` / `m` / `g` suffix, powers of 1024).
    pub fn from_env() -> Option<ArenaConfig> {
        if let Some(v) = crate::knobs::ARENA.raw() {
            if matches!(v.trim(), "off" | "0" | "false" | "dense") {
                return None;
            }
        }
        let page_positions = crate::knobs::KV_PAGE
            .parsed_or(DEFAULT_PAGE_POSITIONS)
            .max(1);
        let budget_bytes = crate::knobs::KV_BUDGET.raw().and_then(|v| parse_bytes(&v));
        Some(ArenaConfig {
            page_positions,
            budget_bytes,
        })
    }
}

fn parse_bytes(s: &str) -> Option<usize> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = t.strip_suffix('g') {
        (d, 1usize << 30)
    } else if let Some(d) = t.strip_suffix('m') {
        (d, 1usize << 20)
    } else if let Some(d) = t.strip_suffix('k') {
        (d, 1usize << 10)
    } else {
        (t.as_str(), 1)
    };
    digits.trim().parse::<usize>().ok().map(|n| n.saturating_mul(mult))
}

/// Handle to one row's page table. Plain index; the arena never reuses
/// a live id, and released ids are recycled only after `release`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TableId(u32);

/// Residency/traffic counters, sampled via [`KvArena::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Pages currently referenced by at least one table.
    pub pages_resident: usize,
    /// High-water mark of resident pages.
    pub pages_high_water: usize,
    /// Cold tables evicted to stay near the budget.
    pub evictions: usize,
    /// Pages deep-copied by copy-on-write divergence after a fork.
    pub fork_pages_copied: usize,
    /// Pages recomputed by the heal path after an eviction.
    pub rehydrated_pages: usize,
    /// Positions per page.
    pub page_positions: usize,
    /// Bytes of one page (K + V blobs).
    pub page_bytes: usize,
    /// Tables currently live (created minus released).
    pub live_tables: usize,
}

struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
    refs: u32,
}

struct Table {
    pages: Vec<u32>,
    /// Valid (resident) positions; always `<=` the owning row's logical
    /// length, and strictly less only right after an eviction.
    positions: usize,
    last_touch: u64,
    /// Pinned tables are never eviction candidates — sessions pin every
    /// row of an in-flight extend batch so one row's page allocation
    /// cannot evict a sibling mid-pass.
    pinned: bool,
    live: bool,
}

/// See module docs. One arena serves one session (single-threaded by
/// construction, like the sessions themselves); the budget is therefore
/// per session.
pub struct KvArena {
    page_positions: usize,
    pos_floats: usize,
    budget_pages: Option<usize>,
    pages: Vec<Page>,
    free_pages: Vec<u32>,
    tables: Vec<Table>,
    free_tables: Vec<u32>,
    clock: u64,
    resident: usize,
    high_water: usize,
    evictions: usize,
    fork_pages_copied: usize,
    rehydrated_pages: usize,
}

impl KvArena {
    /// `pos_floats` is the per-position float cost of ONE of the two
    /// blobs (K or V) across all layers — `n_layers × d_model` for both
    /// cached sessions.
    pub fn new(cfg: &ArenaConfig, pos_floats: usize) -> KvArena {
        let page_positions = cfg.page_positions.max(1);
        let page_bytes = 2 * page_positions * pos_floats * std::mem::size_of::<f32>();
        let budget_pages = cfg
            .budget_bytes
            .map(|b| (b / page_bytes.max(1)).max(1));
        KvArena {
            page_positions,
            pos_floats,
            budget_pages,
            pages: Vec::new(),
            free_pages: Vec::new(),
            tables: Vec::new(),
            free_tables: Vec::new(),
            clock: 0,
            resident: 0,
            high_water: 0,
            evictions: 0,
            fork_pages_copied: 0,
            rehydrated_pages: 0,
        }
    }

    pub fn page_positions(&self) -> usize {
        self.page_positions
    }

    /// Bytes of one page: K blob + V blob.
    pub fn page_bytes(&self) -> usize {
        2 * self.page_positions * self.pos_floats * std::mem::size_of::<f32>()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn insert_table(&mut self, t: Table) -> TableId {
        if let Some(id) = self.free_tables.pop() {
            self.tables[id as usize] = t;
            TableId(id)
        } else {
            self.tables.push(t);
            TableId((self.tables.len() - 1) as u32)
        }
    }

    /// Create an empty table (a fresh row).
    pub fn new_table(&mut self) -> TableId {
        let now = self.tick();
        self.insert_table(Table {
            pages: Vec::new(),
            positions: 0,
            last_touch: now,
            pinned: false,
            live: true,
        })
    }

    /// O(pages) copy-on-write fork: clone the page table, bump page
    /// refcounts. No float is touched until a divergent write.
    pub fn fork(&mut self, src: TableId) -> TableId {
        let now = self.tick();
        let (pages, positions) = {
            let s = &mut self.tables[src.0 as usize];
            debug_assert!(s.live, "fork of a released table");
            s.last_touch = now;
            (s.pages.clone(), s.positions)
        };
        for &p in &pages {
            self.pages[p as usize].refs += 1;
        }
        self.insert_table(Table {
            pages,
            positions,
            last_touch: now,
            pinned: false,
            live: true,
        })
    }

    /// Drop a table, unreferencing all its pages.
    pub fn release(&mut self, t: TableId) {
        let pages = {
            let e = &mut self.tables[t.0 as usize];
            debug_assert!(e.live, "double release of a table");
            e.live = false;
            e.positions = 0;
            e.pinned = false;
            std::mem::take(&mut e.pages)
        };
        for p in pages {
            self.unref_page(p);
        }
        self.free_tables.push(t.0);
    }

    fn unref_page(&mut self, p: u32) {
        let pg = &mut self.pages[p as usize];
        debug_assert!(pg.refs > 0, "unref of a free page");
        pg.refs -= 1;
        if pg.refs == 0 {
            self.resident -= 1;
            self.free_pages.push(p);
        }
    }

    /// Valid resident positions of `t` (the owning row's `kv_valid` for
    /// the rollback helper — less than the row length only after an
    /// eviction).
    pub fn positions(&self, t: TableId) -> usize {
        self.tables[t.0 as usize].positions
    }

    /// Shrink `t` to `positions`, returning whole pages past the cut to
    /// the free list (the partial page containing the new tail stays).
    /// Clamps to the resident count, so callers may pass the row's
    /// logical length even right after an eviction.
    pub fn truncate(&mut self, t: TableId, positions: usize) {
        let keep_pages = {
            let e = &mut self.tables[t.0 as usize];
            debug_assert!(e.live, "truncate of a released table");
            e.positions = e.positions.min(positions);
            e.positions.div_ceil(self.page_positions)
        };
        let drop: Vec<u32> = self.tables[t.0 as usize].pages.split_off(keep_pages);
        for p in drop {
            self.unref_page(p);
        }
    }

    /// Pin/unpin `t` for the duration of an extend batch (pinned tables
    /// are never evicted).
    pub fn set_pinned(&mut self, t: TableId, pinned: bool) {
        let e = &mut self.tables[t.0 as usize];
        debug_assert!(e.live, "pin of a released table");
        e.pinned = pinned;
    }

    /// Make positions `[start, start + m)` of `t` writable and mark them
    /// resident: rolls the table back to `start`, copy-on-writes the
    /// shared partial tail page (the lazy half of an O(pages) fork),
    /// and allocates fresh pages to cover `start + m` — evicting cold
    /// unpinned tables first when the budget is exceeded. Callers then
    /// write K/V through [`KvArena::page_kv_mut`].
    pub fn prepare_append(&mut self, t: TableId, start: usize, m: usize) {
        debug_assert!(
            start <= self.tables[t.0 as usize].positions,
            "append resumes past resident positions"
        );
        self.truncate(t, start);
        let now = self.tick();
        // Protect `t` from the eviction scan while we allocate for it.
        let was_pinned = {
            let e = &mut self.tables[t.0 as usize];
            e.last_touch = now;
            std::mem::replace(&mut e.pinned, true)
        };
        if m > 0 {
            let p = self.page_positions;
            let first = start / p;
            let last = (start + m - 1) / p;
            let n_pages = self.tables[t.0 as usize].pages.len();
            if first < n_pages {
                // The write starts inside the kept partial tail page;
                // unshare it if a fork sibling still references it.
                debug_assert_eq!(first + 1, n_pages);
                let old = self.tables[t.0 as usize].pages[first];
                if self.pages[old as usize].refs > 1 {
                    let _cow = trace_span!(
                        Phase::ArenaCow,
                        (2 * self.page_positions * self.pos_floats * 4) as u64
                    );
                    let new = self.alloc_page();
                    let (kc, vc) = {
                        let s = &self.pages[old as usize];
                        (s.k.clone(), s.v.clone())
                    };
                    {
                        let d = &mut self.pages[new as usize];
                        d.k = kc;
                        d.v = vc;
                    }
                    self.tables[t.0 as usize].pages[first] = new;
                    self.unref_page(old);
                    self.fork_pages_copied += 1;
                }
            }
            for _ in n_pages..=last {
                let new = self.alloc_page();
                self.tables[t.0 as usize].pages.push(new);
            }
            self.tables[t.0 as usize].positions = start + m;
        }
        self.tables[t.0 as usize].pinned = was_pinned;
    }

    fn alloc_page(&mut self) -> u32 {
        // Chaos hook: an injected panic here models a failed page
        // allocation (the real path is infallible Vec growth).
        crate::faults::fire_infallible("arena.alloc");
        if let Some(budget) = self.budget_pages {
            while self.resident >= budget && self.evict_one() {}
        }
        let id = if let Some(id) = self.free_pages.pop() {
            id
        } else {
            let n = self.page_positions * self.pos_floats;
            self.pages.push(Page {
                k: vec![0.0; n],
                v: vec![0.0; n],
                refs: 0,
            });
            (self.pages.len() - 1) as u32
        };
        let pg = &mut self.pages[id as usize];
        debug_assert_eq!(pg.refs, 0, "allocated page still referenced");
        pg.refs = 1;
        self.resident += 1;
        if self.resident > self.high_water {
            self.high_water = self.resident;
        }
        id
    }

    /// Evict the least-recently-touched unpinned table with resident
    /// pages. Its row stays logically valid — the session heals it with
    /// an exact recompute on its next extend. Returns false when no
    /// candidate exists (budget is soft).
    fn evict_one(&mut self) -> bool {
        let mut best: Option<(u64, usize)> = None;
        for (i, e) in self.tables.iter().enumerate() {
            if !e.live || e.pinned || e.pages.is_empty() {
                continue;
            }
            let better = match best {
                None => true,
                Some((lt, _)) => e.last_touch < lt,
            };
            if better {
                best = Some((e.last_touch, i));
            }
        }
        let Some((_, i)) = best else { return false };
        let pages = {
            let e = &mut self.tables[i];
            e.positions = 0;
            std::mem::take(&mut e.pages)
        };
        let _ev = trace_span!(Phase::ArenaEvict, pages.len() as u64);
        for p in pages {
            self.unref_page(p);
        }
        self.evictions += 1;
        true
    }

    /// Record pages recomputed by a heal that resumed below the row's
    /// committed length because of an eviction (stats only).
    pub fn note_rehydrated(&mut self, positions: usize) {
        self.rehydrated_pages += positions.div_ceil(self.page_positions);
    }

    /// The page ids backing `t`, in position order (page `i` holds
    /// positions `[i·P, (i+1)·P)`).
    pub fn table_pages(&self, t: TableId) -> &[u32] {
        &self.tables[t.0 as usize].pages
    }

    /// One page's K blob (`page_positions × pos_floats` floats; layout
    /// within is the owning session's contract).
    pub fn page_k(&self, page: u32) -> &[f32] {
        &self.pages[page as usize].k
    }

    /// One page's V blob.
    pub fn page_v(&self, page: u32) -> &[f32] {
        &self.pages[page as usize].v
    }

    /// Mutable K and V blobs of one page. Callers must hold the page
    /// unshared (via [`KvArena::prepare_append`]) before writing.
    pub fn page_kv_mut(&mut self, page: u32) -> (&mut [f32], &mut [f32]) {
        let pg = &mut self.pages[page as usize];
        debug_assert_eq!(pg.refs, 1, "write to a shared or free page");
        (&mut pg.k, &mut pg.v)
    }

    pub fn live_tables(&self) -> usize {
        self.tables.iter().filter(|e| e.live).count()
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            pages_resident: self.resident,
            pages_high_water: self.high_water,
            evictions: self.evictions,
            fork_pages_copied: self.fork_pages_copied,
            rehydrated_pages: self.rehydrated_pages,
            page_positions: self.page_positions,
            page_bytes: self.page_bytes(),
            live_tables: self.live_tables(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PF: usize = 4; // tiny per-position float cost for tests

    fn arena(page: usize, budget_pages: Option<usize>) -> KvArena {
        let cfg = ArenaConfig {
            page_positions: page,
            budget_bytes: budget_pages.map(|p| p * 2 * page * PF * 4),
        };
        KvArena::new(&cfg, PF)
    }

    fn fill(a: &mut KvArena, t: TableId, start: usize, m: usize, tag: f32) {
        a.prepare_append(t, start, m);
        let p = a.page_positions();
        for pos in start..start + m {
            let pid = a.table_pages(t)[pos / p];
            let slot = pos % p;
            let (k, v) = a.page_kv_mut(pid);
            for f in 0..PF {
                k[slot * PF + f] = tag + pos as f32;
                v[slot * PF + f] = -(tag + pos as f32);
            }
        }
    }

    fn read_k(a: &KvArena, t: TableId, pos: usize) -> f32 {
        let p = a.page_positions();
        let pid = a.table_pages(t)[pos / p];
        a.page_k(pid)[(pos % p) * PF]
    }

    #[test]
    fn fork_shares_pages_and_release_frees_them() {
        let mut a = arena(4, None);
        let t = a.new_table();
        fill(&mut a, t, 0, 10, 100.0);
        assert_eq!(a.positions(t), 10);
        assert_eq!(a.stats().pages_resident, 3);

        let f = a.fork(t);
        // No new pages: the fork shares all three.
        assert_eq!(a.stats().pages_resident, 3);
        assert_eq!(a.table_pages(f), a.table_pages(t));
        assert_eq!(a.positions(f), 10);

        a.release(t);
        assert_eq!(a.stats().pages_resident, 3, "fork keeps pages alive");
        a.release(f);
        assert_eq!(a.stats().pages_resident, 0, "all pages freed at drop");
        assert_eq!(a.live_tables(), 0);
    }

    #[test]
    fn divergent_write_cows_only_the_tail_page() {
        let mut a = arena(4, None);
        let t = a.new_table();
        fill(&mut a, t, 0, 10, 0.0); // pages 0..3, tail page half full
        let f = a.fork(t);

        // Diverge the fork: append 2 positions starting at 10.
        fill(&mut a, f, 10, 2, 50.0);
        let s = a.stats();
        assert_eq!(s.fork_pages_copied, 1, "only the shared tail page copies");
        // Full pages stay shared; the tail page split.
        assert_eq!(&a.table_pages(t)[..2], &a.table_pages(f)[..2]);
        assert_ne!(a.table_pages(t)[2], a.table_pages(f)[2]);
        assert_eq!(s.pages_resident, 4);

        // Parent data is untouched; fork kept the copied prefix.
        assert_eq!(read_k(&a, t, 9), 9.0);
        assert_eq!(read_k(&a, f, 9), 9.0);
        assert_eq!(read_k(&a, f, 11), 61.0);

        a.release(t);
        a.release(f);
        assert_eq!(a.stats().pages_resident, 0);
    }

    #[test]
    fn truncate_releases_whole_pages_and_keeps_the_partial_tail() {
        let mut a = arena(4, None);
        let t = a.new_table();
        fill(&mut a, t, 0, 12, 0.0); // exactly 3 pages
        a.truncate(t, 5);
        assert_eq!(a.positions(t), 5);
        assert_eq!(a.table_pages(t).len(), 2, "partial tail page stays");
        assert_eq!(a.stats().pages_resident, 2);
        // Truncate clamps to resident positions (no-op growth attempt).
        a.truncate(t, 9);
        assert_eq!(a.positions(t), 5);
        a.truncate(t, 0);
        assert_eq!(a.stats().pages_resident, 0);
        a.release(t);
    }

    #[test]
    fn freed_pages_are_reused_not_regrown() {
        let mut a = arena(4, None);
        let t = a.new_table();
        fill(&mut a, t, 0, 8, 0.0);
        a.truncate(t, 0);
        let slab = a.pages.len();
        fill(&mut a, t, 0, 8, 1.0);
        assert_eq!(a.pages.len(), slab, "allocation reuses the free list");
        a.release(t);
        assert_eq!(a.stats().pages_resident, 0);
    }

    #[test]
    fn budget_evicts_the_coldest_unpinned_table() {
        // Budget of 2 pages; page = 4 positions.
        let mut a = arena(4, Some(2));
        let cold = a.new_table();
        fill(&mut a, cold, 0, 8, 0.0); // 2 pages, at budget
        let hot = a.new_table();
        fill(&mut a, hot, 0, 8, 10.0); // must evict `cold`
        let s = a.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(a.positions(cold), 0, "evicted row loses residency");
        assert_eq!(a.positions(hot), 8, "allocating row keeps its pages");
        assert_eq!(s.pages_resident, 2);

        // The evicted table is still usable: rehydrate from scratch.
        fill(&mut a, cold, 0, 3, 20.0);
        a.note_rehydrated(3);
        assert_eq!(a.stats().rehydrated_pages, 1);
        assert_eq!(read_k(&a, cold, 2), 22.0);

        a.release(cold);
        a.release(hot);
        assert_eq!(a.stats().pages_resident, 0);
    }

    #[test]
    fn pinned_tables_survive_budget_pressure() {
        let mut a = arena(4, Some(1));
        let t = a.new_table();
        a.set_pinned(t, true);
        fill(&mut a, t, 0, 12, 0.0); // 3 pages, all over budget
        assert_eq!(a.stats().evictions, 0, "nothing evictable: soft budget");
        assert_eq!(a.positions(t), 12);
        a.set_pinned(t, false);
        let u = a.new_table();
        fill(&mut a, u, 0, 4, 1.0);
        assert!(a.stats().evictions >= 1, "unpinned table now evicts");
        a.release(t);
        a.release(u);
        assert_eq!(a.stats().pages_resident, 0);
    }

    #[test]
    fn prepare_append_heals_from_a_mid_page_start() {
        let mut a = arena(4, None);
        let t = a.new_table();
        fill(&mut a, t, 0, 7, 0.0);
        // Rewind to 5 and append 3: tail page rewritten in place.
        fill(&mut a, t, 5, 3, 30.0);
        assert_eq!(a.positions(t), 8);
        assert_eq!(read_k(&a, t, 4), 4.0, "kept prefix intact");
        assert_eq!(read_k(&a, t, 6), 36.0, "rewound positions rewritten");
        assert_eq!(a.stats().fork_pages_copied, 0, "no sharing, no copy");
        a.release(t);
    }

    #[test]
    fn env_config_parses_budget_suffixes() {
        assert_eq!(parse_bytes("4096"), Some(4096));
        assert_eq!(parse_bytes("64k"), Some(64 << 10));
        assert_eq!(parse_bytes("8M"), Some(8 << 20));
        assert_eq!(parse_bytes("1g"), Some(1 << 30));
        assert_eq!(parse_bytes("nope"), None);
    }
}
