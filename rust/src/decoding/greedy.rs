//! Standard greedy decoding — the paper's baseline for Table 2.

use std::time::Instant;

use anyhow::Result;

use crate::vocab::EOS_ID;

use super::{Backend, DecodeOutput, DecodeStats, DecoderRow, Hypothesis};

/// Greedy-decode one query (batch size 1). `src` is BOS/EOS-wrapped.
pub fn greedy<B: Backend>(backend: &B, src: &[i64]) -> Result<DecodeOutput> {
    let mut out = greedy_batch(backend, &[src])?;
    Ok(out.pop().unwrap())
}

/// Greedy-decode a batch of queries in lock-step, one decoder call per
/// generation step (the Table 2 "B=32" configuration).
///
/// Finished rows keep riding along until every row is done — the standard
/// padded-batch regime whose wall-clock is set by the longest sequence.
pub fn greedy_batch<B: Backend>(backend: &B, srcs: &[&[i64]]) -> Result<Vec<DecodeOutput>> {
    let t0 = Instant::now();
    let dims = backend.dims();
    let memory = backend.encode(srcs)?;
    let mut stats = DecodeStats {
        encoder_calls: 1,
        ..Default::default()
    };

    let n = srcs.len();
    let mut rows: Vec<DecoderRow> = (0..n)
        .map(|i| DecoderRow {
            tokens: vec![crate::vocab::BOS_ID],
            mem_row: i,
        })
        .collect();
    let mut scores = vec![0f64; n];
    let mut done = vec![false; n];

    while !done.iter().all(|&d| d) && rows[0].tokens.len() < dims.t_len {
        let lp = backend.decode(&rows, &memory)?;
        stats.decoder_calls += 1;
        stats.decoder_rows += n;
        for i in 0..n {
            if done[i] {
                // Keep row length in lock-step so the batch stays rectangular
                // after right-alignment; content is ignored.
                rows[i].tokens.push(EOS_ID);
                continue;
            }
            let j = rows[i].tokens.len() - 1;
            let tok = lp.argmax(i, j);
            scores[i] += lp.logp(i, j, tok) as f64;
            rows[i].tokens.push(tok);
            stats.acceptance.total_tokens += 1;
            if tok == EOS_ID {
                done[i] = true;
            }
        }
    }

    let wall = t0.elapsed();
    Ok((0..n)
        .map(|i| {
            let mut tokens: Vec<i64> = rows[i].tokens[1..].to_vec();
            if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
                tokens.truncate(pos);
            }
            let mut s = DecodeStats {
                wall: wall / n as u32,
                ..stats
            };
            // Per-output stats share the batch totals; wall time is
            // apportioned evenly (callers mostly aggregate anyway).
            s.acceptance.total_tokens = tokens.len();
            DecodeOutput {
                hyps: vec![Hypothesis {
                    tokens,
                    score: scores[i],
                }],
                stats: s,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CopyModel;
    use crate::vocab::BOS_ID;

    #[test]
    fn greedy_copies_through_copy_model() {
        // CopyModel's target is a deterministic function of the source;
        // greedy must recover it exactly.
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, crate::vocab::EOS_ID];
        let out = greedy(&m, &src).unwrap();
        assert_eq!(out.hyps.len(), 1);
        assert_eq!(out.hyps[0].tokens, m.target_for(&src));
        assert!(out.stats.decoder_calls >= out.hyps[0].tokens.len());
    }

    #[test]
    fn greedy_batch_matches_single() {
        let m = CopyModel::new(96, 96, 40);
        let a = vec![BOS_ID, 10, 11, 12, crate::vocab::EOS_ID];
        let b = vec![BOS_ID, 20, 21, 22, 23, 24, crate::vocab::EOS_ID];
        let batch = greedy_batch(&m, &[&a, &b]).unwrap();
        let sa = greedy(&m, &a).unwrap();
        let sb = greedy(&m, &b).unwrap();
        assert_eq!(batch[0].hyps[0].tokens, sa.hyps[0].tokens);
        assert_eq!(batch[1].hyps[0].tokens, sb.hyps[0].tokens);
        // Lock-step batching: decoder calls = max of individual runs.
        assert_eq!(
            batch[0].stats.decoder_calls,
            sa.stats.decoder_calls.max(sb.stats.decoder_calls)
        );
    }

    #[test]
    fn greedy_terminates_without_eos() {
        // A model that never emits EOS must stop at the window limit.
        let m = CopyModel::never_eos(16, 16, 40);
        let src = vec![BOS_ID, 10, 11, crate::vocab::EOS_ID];
        let out = greedy(&m, &src).unwrap();
        assert_eq!(out.hyps[0].tokens.len(), 15); // t_len - BOS
    }
}
