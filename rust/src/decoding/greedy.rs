//! Standard greedy decoding — the paper's baseline for Table 2 — on
//! incremental sessions.
//!
//! The decoding state lives in a [`GreedyRun`]: one session row per
//! query ("lane"), extended by exactly one token per step, so a
//! KV-cached backend computes one position per lane per step instead of
//! re-running the whole prefix. Lanes can be admitted while the run is
//! live (the coordinator's continuous batching); a freshly admitted lane
//! simply joins the next step's `extend` call.

use std::time::Instant;

use anyhow::Result;

use crate::trace::{self, Phase};
use crate::trace_span;
use crate::vocab::{BOS_ID, EOS_ID};

use super::{Backend, DecodeOutput, DecodeStats, DecoderSession, Hypothesis, SessionStats};

struct Lane {
    row: usize,
    /// BOS + emitted tokens (including EOS once emitted).
    tokens: Vec<i64>,
    /// How many of `tokens` the session has committed (computed).
    sess_len: usize,
    score: f64,
    done: bool,
}

/// A live greedy decode over a [`DecoderSession`]. See module docs.
pub struct GreedyRun<'a> {
    sess: Box<dyn DecoderSession + 'a>,
    lanes: Vec<Lane>,
    calls: usize,
    rows_submitted: usize,
}

impl<'a> GreedyRun<'a> {
    pub fn new(sess: Box<dyn DecoderSession + 'a>) -> GreedyRun<'a> {
        GreedyRun {
            sess,
            lanes: Vec::new(),
            calls: 0,
            rows_submitted: 0,
        }
    }

    /// Mutable access to the underlying session (for `append_memory`
    /// when admitting new queries into a live run).
    pub fn session_mut(&mut self) -> &mut (dyn DecoderSession + 'a) {
        &mut *self.sess
    }

    /// Add a lane decoding against `mem_row`. Returns the lane id.
    pub fn admit(&mut self, mem_row: usize) -> usize {
        let row = self.sess.new_row(mem_row);
        self.lanes.push(Lane {
            row,
            tokens: vec![BOS_ID],
            sess_len: 0,
            score: 0.0,
            done: false,
        });
        self.lanes.len() - 1
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn n_live(&self) -> usize {
        self.lanes.iter().filter(|l| !l.done).count()
    }

    pub fn finished(&self) -> bool {
        self.lanes.iter().all(|l| l.done)
    }

    pub fn calls(&self) -> usize {
        self.calls
    }

    pub fn rows_submitted(&self) -> usize {
        self.rows_submitted
    }

    pub fn session_stats(&self) -> SessionStats {
        self.sess.stats()
    }

    /// One lock-step generation step across all live lanes (one decoder
    /// call). Returns the lanes that finished on this step.
    pub fn step(&mut self) -> Result<Vec<usize>> {
        let t_len = self.sess.dims().t_len;
        let mut idxs: Vec<usize> = Vec::new();
        let mut deltas: Vec<(usize, &[i64])> = Vec::new();
        for (li, lane) in self.lanes.iter().enumerate() {
            if lane.done {
                continue;
            }
            idxs.push(li);
            deltas.push((lane.row, &lane.tokens[lane.sess_len..]));
        }
        if idxs.is_empty() {
            return Ok(Vec::new());
        }
        crate::faults::fire("decoder.extend")?;
        let lp = {
            let _ext = trace_span!(Phase::Extend, deltas.len() as u64);
            self.sess.extend(&deltas)?
        };
        self.calls += 1;
        self.rows_submitted += deltas.len();
        drop(deltas);

        let mut just_finished = Vec::new();
        for (k, &li) in idxs.iter().enumerate() {
            let lane = &mut self.lanes[li];
            lane.sess_len = lane.tokens.len();
            let j = lane.tokens.len() - 1;
            let tok = lp.argmax(k, j);
            lane.score += lp.logp(k, j, tok) as f64;
            lane.tokens.push(tok);
            if tok == EOS_ID || lane.tokens.len() >= t_len {
                lane.done = true;
                just_finished.push(li);
            }
        }
        for &li in &just_finished {
            // The trailing token is never committed; free the row's cache.
            self.sess.release(self.lanes[li].row);
        }
        Ok(just_finished)
    }

    /// The decoded hypothesis of a finished (or still running) lane:
    /// generated tokens without BOS, truncated at EOS.
    pub fn hypothesis(&self, lane: usize) -> Hypothesis {
        let l = &self.lanes[lane];
        let mut tokens: Vec<i64> = l.tokens[1..].to_vec();
        if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
            tokens.truncate(pos);
        }
        Hypothesis {
            tokens,
            score: l.score,
        }
    }
}

/// Greedy-decode one query (batch size 1). `src` is BOS/EOS-wrapped.
pub fn greedy<B: Backend>(backend: &B, src: &[i64]) -> Result<DecodeOutput> {
    let mut out = greedy_batch(backend, &[src])?;
    Ok(out.pop().unwrap())
}

/// Greedy-decode a batch of queries in lock-step, one decoder call per
/// generation step (the Table 2 "B=32" configuration).
pub fn greedy_batch<B: Backend>(backend: &B, srcs: &[&[i64]]) -> Result<Vec<DecodeOutput>> {
    let t0 = Instant::now();
    let ph0 = trace::thread_phase_ns();
    let memory = {
        let _enc = trace_span!(Phase::Encode, srcs.len() as u64);
        backend.encode(srcs)?
    };
    let n = srcs.len();
    let sess = {
        let _beg = trace_span!(Phase::SessionBegin);
        backend.begin(memory)?
    };
    let mut run = GreedyRun::new(sess);
    for i in 0..n {
        run.admit(i);
    }
    while !run.finished() {
        run.step()?;
    }
    let wall = t0.elapsed();
    // Phase attribution from the trace layer: spans on this thread
    // accumulated into per-phase counters; the diff over this decode,
    // apportioned per query like `wall`, is each output's share. All
    // zero when RXNSPEC_TRACE is off.
    let ph1 = trace::thread_phase_ns();
    let phase_us =
        |p: Phase| ph1[p as usize].saturating_sub(ph0[p as usize]) / 1000 / n as u64;

    let sess = run.session_stats();
    let base = DecodeStats {
        decoder_calls: run.calls(),
        encoder_calls: 1,
        decoder_rows: run.rows_submitted(),
        tokens_computed: sess.tokens_computed,
        tokens_reused: sess.tokens_reused,
        encode_us: phase_us(Phase::Encode),
        extend_us: phase_us(Phase::Extend),
        verify_us: phase_us(Phase::Verify),
        ..Default::default()
    };
    Ok((0..n)
        .map(|i| {
            let hyp = run.hypothesis(i);
            let mut s = base;
            // Per-output stats share the batch totals; wall time is
            // apportioned evenly (callers mostly aggregate anyway).
            s.wall = wall / n as u32;
            s.acceptance.total_tokens = hyp.tokens.len();
            DecodeOutput {
                hyps: vec![hyp],
                stats: s,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CopyModel;
    use crate::vocab::BOS_ID;

    #[test]
    fn greedy_copies_through_copy_model() {
        // CopyModel's target is a deterministic function of the source;
        // greedy must recover it exactly.
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, crate::vocab::EOS_ID];
        let out = greedy(&m, &src).unwrap();
        assert_eq!(out.hyps.len(), 1);
        assert_eq!(out.hyps[0].tokens, m.target_for(&src));
        assert!(out.stats.decoder_calls >= out.hyps[0].tokens.len());
    }

    #[test]
    fn greedy_batch_matches_single() {
        let m = CopyModel::new(96, 96, 40);
        let a = vec![BOS_ID, 10, 11, 12, crate::vocab::EOS_ID];
        let b = vec![BOS_ID, 20, 21, 22, 23, 24, crate::vocab::EOS_ID];
        let batch = greedy_batch(&m, &[&a, &b]).unwrap();
        let sa = greedy(&m, &a).unwrap();
        let sb = greedy(&m, &b).unwrap();
        assert_eq!(batch[0].hyps[0].tokens, sa.hyps[0].tokens);
        assert_eq!(batch[1].hyps[0].tokens, sb.hyps[0].tokens);
        // Lock-step batching: decoder calls = max of individual runs.
        assert_eq!(
            batch[0].stats.decoder_calls,
            sa.stats.decoder_calls.max(sb.stats.decoder_calls)
        );
    }

    #[test]
    fn greedy_terminates_without_eos() {
        // A model that never emits EOS must stop at the window limit.
        let m = CopyModel::never_eos(16, 16, 40);
        let src = vec![BOS_ID, 10, 11, crate::vocab::EOS_ID];
        let out = greedy(&m, &src).unwrap();
        assert_eq!(out.hyps[0].tokens.len(), 15); // t_len - BOS
    }

    #[test]
    fn stats_track_stateless_recompute_cost() {
        // Through the StatelessSession every step recomputes the whole
        // prefix: Σ_{k=1..L+1} k positions for L generated tokens + EOS.
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, crate::vocab::EOS_ID];
        let out = greedy(&m, &src).unwrap();
        let l = out.hyps[0].tokens.len(); // 3 + EOS step = 4 calls
        let expect: usize = (1..=l + 1).sum();
        assert_eq!(out.stats.tokens_computed, expect);
        assert_eq!(out.stats.tokens_reused, 0);
        assert!(out.stats.recompute_per_token() > 1.0);
    }

    #[test]
    fn lanes_admitted_mid_run_finish_correctly() {
        // Simulates the coordinator admitting a query into a live
        // session between batching ticks.
        let m = CopyModel::new(96, 96, 40);
        let a: Vec<i64> = vec![BOS_ID, 10, 11, 12, 13, 14, crate::vocab::EOS_ID];
        let b: Vec<i64> = vec![BOS_ID, 20, 21, crate::vocab::EOS_ID];
        let memory = m.encode(&[&a]).unwrap();
        let mut run = GreedyRun::new(m.begin(memory).unwrap());
        let la = run.admit(0);
        run.step().unwrap();
        run.step().unwrap();
        // Newcomer joins after two ticks.
        let extra = m.encode(&[&b]).unwrap();
        let base = run.session_mut().append_memory(&extra);
        let lb = run.admit(base);
        while !run.finished() {
            run.step().unwrap();
        }
        assert_eq!(run.hypothesis(la).tokens, m.target_for(&a));
        assert_eq!(run.hypothesis(lb).tokens, m.target_for(&b));
    }
}
