//! Decoding algorithms over an abstract model backend, built on
//! **incremental decoding sessions**.
//!
//! This module implements the paper's contribution: standard greedy and
//! beam-search decoding, plus their speculative counterparts that copy
//! query-SMILES subsequences into the target (§2.1 and Appendix B).
//!
//! # The session model
//!
//! Speculative decoding cuts the *number* of decoder calls, but a
//! stateless `decode(rows)` interface still recomputes self-attention
//! over the full prefix on every call, so per-step cost grows
//! quadratically with target length. The companion optimization is KV
//! caching: [`Backend::begin`] opens a [`DecoderSession`] that owns the
//! encoder memory plus per-row decoder state, and exposes
//!
//! * [`DecoderSession::extend`] — append a window of tokens to chosen
//!   rows and run **one** decoder forward pass over just the appended
//!   region (the prefix's K/V come from the cache),
//! * [`DecoderSession::truncate`] — roll back rejected draft tokens,
//! * [`DecoderSession::fork`] — cheap copy-on-write branching for
//!   beam-search / SBS hypotheses,
//! * [`DecoderSession::append_memory`] — admit new queries into a live
//!   session (the coordinator's continuous batching).
//!
//! Every decoder in this module drives a session; backends without a
//! cache-aware implementation get the [`StatelessSession`] adapter, which
//! reproduces the old recompute-everything behaviour behind the same
//! interface. The *conditional-consistency contract* (below) makes
//! cached and stateless decoding **token-exact equal** — property tests
//! in `rust/tests/session_parity.rs` hold this as a hard invariant, not
//! a tolerance check.
//!
//! All algorithms are generic over [`Backend`], which is implemented by
//! the PJRT runtime (`runtime::PjrtBackend`, the production path, with a
//! KV-cached session over `deccache` artifacts and a stateless-recompute
//! fallback for artifact sets without them), by the pure-Rust reference
//! transformer (`model::reference`, with a real KV-cached session), and
//! by deterministic mock models (`testutil`) used to property-test the
//! algorithm invariants:
//!
//! * speculative greedy is **token-exact** vs greedy,
//! * speculative beam search with a never-accepted draft reduces to
//!   standard beam search,
//! * session-cached decoding is **token-exact** vs stateless decoding,
//! * acceptance statistics are consistent with emitted tokens.

pub mod arena;
mod beam;
mod greedy;
mod sbs;
pub(crate) mod session;
mod spec_greedy;

pub use arena::{ArenaConfig, ArenaStats, KvArena, TableId};
pub use beam::beam_search;
pub use greedy::{greedy, greedy_batch, GreedyRun};
pub use sbs::{hyps_to_smiles, sbs, sbs_traced, SbsConfig, SbsIterTrace, SbsTrace};
pub use session::StatelessSession;
pub use spec_greedy::{
    spec_greedy, spec_greedy_batch, spec_greedy_batch_corpus, spec_greedy_corpus, SpecGreedyRun,
};

use std::time::Duration;

use anyhow::Result;

use crate::draft::Acceptance;

/// Static model dimensions shared by every backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    /// Source bucket length (tokens incl. BOS/EOS).
    pub s_len: usize,
    /// Target bucket length (decoder context window incl. BOS).
    pub t_len: usize,
    /// Embedding width.
    pub d_model: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

/// Encoder output held host-side: row-major `[batch, s_len, d_model]`
/// activations plus the source pad mask `[batch, s_len]` (1.0 = real).
#[derive(Debug, Clone)]
pub struct Memory {
    pub data: Vec<f32>,
    pub pad: Vec<f32>,
    pub batch: usize,
    pub s_len: usize,
    pub d_model: usize,
}

impl Memory {
    /// Borrow one row's activations.
    pub fn row(&self, b: usize) -> &[f32] {
        let n = self.s_len * self.d_model;
        &self.data[b * n..(b + 1) * n]
    }

    /// Borrow one row's pad mask.
    pub fn pad_row(&self, b: usize) -> &[f32] {
        &self.pad[b * self.s_len..(b + 1) * self.s_len]
    }
}

/// One decoder input row: an unpadded token sequence (starting with BOS)
/// and the index of the encoder-memory row it attends to.
#[derive(Debug, Clone)]
pub struct DecoderRow {
    pub tokens: Vec<i64>,
    pub mem_row: usize,
}

/// Log-probabilities returned by one decoder forward pass.
///
/// Storage is `[rows, t_len, vocab]`; rows were right-aligned (left-padded)
/// into the fixed window by the backend, so position `j` of row `i` (in the
/// row's own coordinates) lives at column `t_len - len_i + j`. The paper's
/// `padLeft` (Appendix B) exists for exactly this: ragged candidate rows
/// share fixed-shape batches while positional encodings stay contiguous.
#[derive(Debug, Clone)]
pub struct LogProbs {
    data: Vec<f32>,
    row_lens: Vec<usize>,
    t_len: usize,
    vocab: usize,
    /// Number of trailing columns actually stored. Full-window backends
    /// store all `t_len` columns; the decfast artifact stores only the
    /// last `window` (everything a decoding step reads — prefix head plus
    /// draft verify region).
    window: usize,
}

impl LogProbs {
    pub fn new(data: Vec<f32>, row_lens: Vec<usize>, t_len: usize, vocab: usize) -> LogProbs {
        debug_assert_eq!(data.len(), row_lens.len() * t_len * vocab);
        LogProbs {
            data,
            row_lens,
            t_len,
            vocab,
            window: t_len,
        }
    }

    /// Windowed storage: `data` holds only the trailing `window` columns
    /// of each row.
    pub fn new_windowed(
        data: Vec<f32>,
        row_lens: Vec<usize>,
        t_len: usize,
        vocab: usize,
        window: usize,
    ) -> LogProbs {
        debug_assert_eq!(data.len(), row_lens.len() * window * vocab);
        LogProbs {
            data,
            row_lens,
            t_len,
            vocab,
            window,
        }
    }

    pub fn n_rows(&self) -> usize {
        self.row_lens.len()
    }

    fn offset(&self, row: usize, j: usize) -> usize {
        // Absolute column in the padded layout, then relative to the
        // stored window's first column.
        let col = self.t_len - self.row_lens[row] + j;
        assert!(
            col + self.window >= self.t_len,
            "position {j} of row {row} (len {}) is outside the stored window {}",
            self.row_lens[row],
            self.window
        );
        let wcol = col + self.window - self.t_len;
        (row * self.window + wcol) * self.vocab
    }

    /// Log-probability of `tok` as the successor of position `j` (row
    /// coordinates: `j = 0` is BOS, the prediction for the first real
    /// token).
    pub fn logp(&self, row: usize, j: usize, tok: i64) -> f32 {
        self.data[self.offset(row, j) + tok as usize]
    }

    /// Full successor distribution at position `j` of `row`.
    pub fn dist(&self, row: usize, j: usize) -> &[f32] {
        let off = self.offset(row, j);
        &self.data[off..off + self.vocab]
    }

    /// Argmax successor at position `j` of `row` (ties → lowest id, which
    /// both backends and the HLO artifact share as the convention).
    pub fn argmax(&self, row: usize, j: usize) -> i64 {
        let d = self.dist(row, j);
        let mut best = 0usize;
        for (i, &v) in d.iter().enumerate() {
            if v > d[best] {
                best = i;
            }
        }
        best as i64
    }

    /// Top-`k` successors at position `j` of `row`, sorted descending by
    /// log-probability (ties → lowest id first).
    ///
    /// Uses `select_nth_unstable_by` to partition the top `k` in O(V)
    /// before sorting only those — beam search calls this per kept beam
    /// per step, and the old full O(V log V) sort was pure overhead for
    /// k ≪ V. The documented tie-break (lowest id first among equal
    /// log-probs) is part of the comparator, so partial selection keeps
    /// the exact same output as the full sort.
    pub fn topk(&self, row: usize, j: usize, k: usize) -> Vec<(i64, f32)> {
        let d = self.dist(row, j);
        let k = k.min(d.len());
        if k == 0 {
            return Vec::new();
        }
        let cmp =
            |a: &usize, b: &usize| d[*b].partial_cmp(&d[*a]).unwrap().then(a.cmp(b));
        let mut idx: Vec<usize> = (0..d.len()).collect();
        if k < idx.len() {
            idx.select_nth_unstable_by(k - 1, cmp);
            idx.truncate(k);
        }
        idx.sort_by(cmp);
        idx.into_iter().map(|i| (i as i64, d[i])).collect()
    }
}

/// The model interface the decoding algorithms run against.
///
/// Implementations must guarantee the *conditional-consistency contract*:
/// the successor distribution at position `j` of a row depends only on the
/// row's tokens `0..=j` and its memory row — never on other rows in the
/// batch or on padding. Speculative decoding's losslessness — and the
/// token-exactness of session caching — rest on this.
pub trait Backend {
    fn dims(&self) -> ModelDims;

    /// Encode a batch of BOS/EOS-wrapped source sequences.
    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory>;

    /// One decoder forward pass over `rows` (each row unpadded, starting
    /// with BOS; backends right-align into the fixed window).
    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs>;

    /// Open an incremental decoding session over `memory`.
    ///
    /// The default wraps the backend in a [`StatelessSession`], which
    /// re-submits full prefixes through [`Backend::decode`] — correct
    /// for every conditionally-consistent backend, with no caching win.
    /// Cache-aware backends (the pure-Rust reference transformer)
    /// override this with sessions that reuse per-layer K/V state.
    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>>
    where
        Self: Sized,
    {
        Ok(Box::new(StatelessSession::new(self, memory)))
    }
}

/// Accounting for one [`DecoderSession`]: how much decoder work was done
/// vs served from cache. `tokens_computed + tokens_reused` is the
/// stateless-equivalent position count; the ratio is the FLOPs-proxy win.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Decoder forward passes issued by `extend`.
    pub extend_calls: usize,
    /// Token positions actually computed (embedding + attention + FFN).
    pub tokens_computed: usize,
    /// Token positions whose per-layer K/V were reused from the cache
    /// (a stateless backend would have recomputed them).
    pub tokens_reused: usize,
    /// Rows submitted across all `extend` calls. For backends with a
    /// cross-row batched extend (the reference transformer) every call's
    /// rows share one packed layer pass, so `packed_rows / extend_calls`
    /// is the mean packed-batch size per tick.
    pub packed_rows: usize,
    /// High-water mark of per-row retained log-prob positions (the
    /// bounded `RowCache::lp` suffix; 0 for backends without one).
    pub lp_high_water: usize,
    /// Encoder passes that fed this session's memory (one for `begin`,
    /// one per `append_memory`).
    pub encode_calls: usize,
    /// Source rows across those passes. The reference backend packs
    /// every pass's rows into one activation matrix per encoder layer,
    /// so `packed_src_rows / encode_calls` is the mean packed encoder
    /// batch per call.
    pub packed_src_rows: usize,
    /// Paged-KV-arena pages resident when `stats()` was read (0 on the
    /// dense `RXNSPEC_ARENA=off` path and for sessions without K/V).
    pub kv_pages_resident: usize,
    /// High-water mark of resident arena pages.
    pub kv_pages_high_water: usize,
    /// Bytes of one arena page (K + V blobs); `kv_pages_high_water ×
    /// kv_page_bytes` is the session's peak K/V footprint.
    pub kv_page_bytes: usize,
    /// Cold rows evicted from the arena under `RXNSPEC_KV_BUDGET`.
    pub arena_evictions: usize,
    /// Pages deep-copied by copy-on-write divergence after `fork`.
    pub fork_pages_copied: usize,
}

/// One live incremental decode: per-row token state plus whatever cache
/// the backend keeps (per-layer K/V for the reference transformer, plain
/// token buffers for the stateless adapter).
///
/// Row ids are session-local handles. All mutators panic on a released
/// row id — that is a decoder bug, not a recoverable condition.
pub trait DecoderSession {
    fn dims(&self) -> ModelDims;

    /// The encoder memory this session decodes against.
    fn memory(&self) -> &Memory;

    /// Append freshly encoded rows to the session memory (continuous
    /// batching: new queries joining a live session). Returns the index
    /// of the first appended memory row.
    fn append_memory(&mut self, extra: &Memory) -> usize;

    /// Create an empty row attending to `mem_row`. Returns its id.
    fn new_row(&mut self, mem_row: usize) -> usize;

    /// Copy-on-write clone of `row`'s state. Returns the new row id.
    fn fork(&mut self, row: usize) -> usize;

    /// Roll `row` back to its first `len` tokens (`len` ≤ current).
    fn truncate(&mut self, row: usize, len: usize);

    /// Drop a row, freeing its cache. The id must not be used again.
    fn release(&mut self, row: usize);

    /// Current committed token count of `row`.
    fn row_len(&self, row: usize) -> usize;

    /// Append `tokens` to each listed row (ids must be distinct) and run
    /// **one** decoder forward pass over the appended windows.
    ///
    /// The result's rows are indexed in `deltas` order with `row_lens`
    /// equal to the post-append lengths, and its stored window covers at
    /// least positions `j ∈ [max(len_before - 1, 0), len_after - 1]` of
    /// each row — the successor distributions of the last pre-extend
    /// token and of every appended token, i.e. everything needed to emit
    /// the next token and to verify the appended draft region.
    fn extend(&mut self, deltas: &[(usize, &[i64])]) -> Result<LogProbs>;

    /// Cache accounting so far.
    fn stats(&self) -> SessionStats;
}

/// Instrumentation for one decode run.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecodeStats {
    /// Decoder forward passes (the paper's "calls to the model").
    pub decoder_calls: usize,
    /// Encoder forward passes.
    pub encoder_calls: usize,
    /// Total decoder rows across all calls (effective batch · calls).
    pub decoder_rows: usize,
    /// Decoder token positions actually computed across all calls.
    pub tokens_computed: usize,
    /// Token positions served from a session K/V cache instead of being
    /// recomputed (always 0 on the stateless path).
    pub tokens_reused: usize,
    /// Draft-token acceptance accounting.
    pub acceptance: Acceptance,
    /// Accepted draft tokens that came from query-copy windows
    /// (`DraftSource::QueryCopy`).
    pub accepted_query_tokens: usize,
    /// Accepted draft tokens that came from corpus-learned windows
    /// (`DraftSource::Corpus`, mined by a `cache::DraftStore`).
    pub accepted_corpus_tokens: usize,
    /// Wall time of the whole decode.
    pub wall: Duration,
    /// Wall time attributed to encoder passes (µs), populated from the
    /// trace layer's per-thread phase accumulators. Zero when
    /// `RXNSPEC_TRACE` is off — by construction the trace layer never
    /// changes decoded outputs or the token counters above.
    pub encode_us: u64,
    /// Wall time attributed to KV-cached `extend` passes (µs; traced).
    pub extend_us: u64,
    /// Wall time attributed to draft verification (µs; traced).
    pub verify_us: u64,
}

impl DecodeStats {
    pub fn merge(&mut self, o: &DecodeStats) {
        self.decoder_calls += o.decoder_calls;
        self.encoder_calls += o.encoder_calls;
        self.decoder_rows += o.decoder_rows;
        self.tokens_computed += o.tokens_computed;
        self.tokens_reused += o.tokens_reused;
        self.acceptance.merge(&o.acceptance);
        self.accepted_query_tokens += o.accepted_query_tokens;
        self.accepted_corpus_tokens += o.accepted_corpus_tokens;
        self.wall += o.wall;
        self.encode_us += o.encode_us;
        self.extend_us += o.extend_us;
        self.verify_us += o.verify_us;
    }

    /// Absorb a finished session's cache accounting.
    pub fn absorb_session(&mut self, s: &SessionStats) {
        self.tokens_computed += s.tokens_computed;
        self.tokens_reused += s.tokens_reused;
    }

    /// The per-step decoder FLOPs proxy: token positions computed per
    /// emitted token. Stateless greedy pays ~L/2 here (it recomputes the
    /// whole prefix every step); a KV-cached session pays ~1.
    pub fn recompute_per_token(&self) -> f64 {
        if self.acceptance.total_tokens == 0 {
            0.0
        } else {
            self.tokens_computed as f64 / self.acceptance.total_tokens as f64
        }
    }

    /// Fraction of stateless-equivalent positions served from cache.
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.tokens_computed + self.tokens_reused;
        if total == 0 {
            0.0
        } else {
            self.tokens_reused as f64 / total as f64
        }
    }
}

/// One decoded hypothesis: generated token ids (no BOS, no EOS) and its
/// cumulative log-probability (including EOS if the model emitted it).
#[derive(Debug, Clone, PartialEq)]
pub struct Hypothesis {
    pub tokens: Vec<i64>,
    pub score: f64,
}

/// Result of decoding one query: hypotheses sorted by descending score
/// (a single one for greedy decoders) plus run statistics.
#[derive(Debug, Clone)]
pub struct DecodeOutput {
    pub hyps: Vec<Hypothesis>,
    pub stats: DecodeStats,
}

/// Clip a draft so that `prefix + draft` fits the decoder window.
pub(crate) fn clip_draft<'a>(draft: &'a [i64], prefix_len: usize, t_len: usize) -> &'a [i64] {
    let room = t_len.saturating_sub(prefix_len);
    &draft[..draft.len().min(room)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logprobs_indexing_right_aligned() {
        // 1 row, t_len 4, vocab 2, row len 2 → row occupies columns 2..4.
        let mut data = vec![f32::NAN; 8];
        // column 2 (j=0): [0.1, 0.9]; column 3 (j=1): [0.7, 0.3]
        data[2 * 2] = 0.1;
        data[2 * 2 + 1] = 0.9;
        data[3 * 2] = 0.7;
        data[3 * 2 + 1] = 0.3;
        let lp = LogProbs::new(data, vec![2], 4, 2);
        assert_eq!(lp.logp(0, 0, 1), 0.9);
        assert_eq!(lp.argmax(0, 0), 1);
        assert_eq!(lp.argmax(0, 1), 0);
        let top = lp.topk(0, 1, 2);
        assert_eq!(top[0].0, 0);
        assert_eq!(top[1].0, 1);
    }

    #[test]
    fn topk_breaks_ties_by_lowest_id() {
        let data = vec![0.5, 0.5, 0.1];
        let lp = LogProbs::new(data, vec![1], 1, 3);
        let top = lp.topk(0, 0, 3);
        assert_eq!(top.iter().map(|t| t.0).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(lp.argmax(0, 0), 0);
    }

    #[test]
    fn topk_partial_selection_pins_tie_order() {
        // Ties straddling the selection cut: ids 0, 2, 3 share 0.5; with
        // k = 3 the partial selection must keep exactly {1, 0, 2} and
        // order them (0.7, id 1) then the 0.5s by ascending id — the
        // same output the old full sort produced.
        let data = vec![0.5, 0.7, 0.5, 0.5, 0.2];
        let lp = LogProbs::new(data, vec![1], 1, 5);
        let top = lp.topk(0, 0, 3);
        assert_eq!(
            top.iter().map(|t| t.0).collect::<Vec<_>>(),
            vec![1, 0, 2]
        );
        // k larger than vocab degrades gracefully to a full sort.
        let all = lp.topk(0, 0, 99);
        assert_eq!(
            all.iter().map(|t| t.0).collect::<Vec<_>>(),
            vec![1, 0, 2, 3, 4]
        );
        assert!(lp.topk(0, 0, 0).is_empty());
    }

    #[test]
    fn windowed_logprobs_map_trailing_columns() {
        // t_len 8, window 4, vocab 2, one row of len 3: row occupies
        // columns 5..8; stored window covers columns 4..8.
        let mut data = vec![f32::NAN; 4 * 2];
        // j=0 → col 5 → wcol 1 ; j=2 → col 7 → wcol 3
        data[1 * 2] = 0.25;
        data[1 * 2 + 1] = 0.75;
        data[3 * 2] = 0.9;
        data[3 * 2 + 1] = 0.1;
        let lp = LogProbs::new_windowed(data, vec![3], 8, 2, 4);
        assert_eq!(lp.logp(0, 0, 1), 0.75);
        assert_eq!(lp.argmax(0, 0), 1);
        assert_eq!(lp.argmax(0, 2), 0);
    }

    #[test]
    #[should_panic]
    fn windowed_logprobs_reject_out_of_window_reads() {
        // Row len 6 with window 4: positions j < 2 live outside storage.
        let data = vec![0f32; 4 * 2];
        let lp = LogProbs::new_windowed(data, vec![6], 8, 2, 4);
        let _ = lp.logp(0, 0, 0);
    }

    #[test]
    fn clip_draft_respects_window() {
        let d = vec![1, 2, 3, 4, 5];
        assert_eq!(clip_draft(&d, 10, 16), &[1, 2, 3, 4, 5]);
        assert_eq!(clip_draft(&d, 14, 16), &[1, 2]);
        assert_eq!(clip_draft(&d, 16, 16), &[] as &[i64]);
    }

    #[test]
    fn memory_row_access() {
        let m = Memory {
            data: (0..12).map(|x| x as f32).collect(),
            pad: vec![1.0, 1.0, 0.0, 1.0, 0.0, 0.0],
            batch: 2,
            s_len: 3,
            d_model: 2,
        };
        assert_eq!(m.row(1), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(m.pad_row(1), &[1.0, 0.0, 0.0]);
    }
}
