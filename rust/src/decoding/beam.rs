//! Standard beam search — the paper's baseline for Tables 3 and 4.
//!
//! Hypotheses are ranked by **length-normalized** log-probability (mean
//! log-prob per generated token), the convention of OpenNMT-style
//! Molecular Transformer decoding. Normalization is what lets the paper's
//! speculative variant compare candidate sequences of *unequal lengths*
//! fairly (Figure 3 keeps a 4-token and an 11-token candidate side by
//! side): under a raw sum of negative log-probs a sequence could never
//! outrank its own prefix, and speculative progress would collapse to one
//! token per call.
//!
//! Search stops once `n` finished hypotheses (EOS emitted) have been
//! collected or no live beams remain; each surviving beam grows by at
//! least one token per iteration, so the loop is bounded by the window.

use std::time::Instant;

use crate::trace::{self, Phase};
use crate::trace_span;

use anyhow::Result;

use crate::vocab::{BOS_ID, EOS_ID};

use super::{Backend, DecodeOutput, DecodeStats, Hypothesis};

/// A live (unfinished) beam: tokens include the leading BOS; `score` is
/// the raw cumulative log-probability of the generated tokens.
#[derive(Debug, Clone)]
pub(crate) struct BeamState {
    pub tokens: Vec<i64>,
    pub score: f64,
}

impl BeamState {
    /// Mean log-prob per generated token — the ranking key.
    pub fn norm(&self) -> f64 {
        let n = self.tokens.len().saturating_sub(1).max(1);
        self.score / n as f64
    }
}

/// Canonical candidate order: normalized score descending, lexicographic
/// tokens as the deterministic tie-break. Both `beam_search` and `sbs`
/// must use this exact order so their survivors coincide (Table 4).
/// Generic over the container so candidates can carry session-row
/// bookkeeping alongside their [`BeamState`].
pub(crate) fn rank_by<T>(v: &mut [T], key: impl Fn(&T) -> &BeamState) {
    v.sort_by(|a, b| {
        let (a, b) = (key(a), key(b));
        b.norm()
            .partial_cmp(&a.norm())
            .unwrap()
            .then_with(|| a.tokens.cmp(&b.tokens))
    });
}


/// Collector for finished hypotheses shared by `beam_search` and `sbs`.
pub(crate) struct BeamPool {
    pub n: usize,
    finished: Vec<(Hypothesis, f64)>, // (hypothesis, normalized score)
}

impl BeamPool {
    pub fn new(n: usize) -> Self {
        BeamPool {
            n,
            finished: Vec::new(),
        }
    }

    /// Retire a finished beam. `tokens_with_bos` excludes the EOS itself;
    /// `score` includes the EOS log-prob; `gen_len` is the number of
    /// generated tokens the normalization divides by (incl. EOS).
    ///
    /// Deduplicates on token content: in SBS a surviving prefix beam can
    /// re-derive an already-finished extension on a later iteration, and
    /// duplicate pool entries would both waste hypothesis slots and trip
    /// the stop rule early.
    pub fn push_finished(&mut self, tokens_with_bos: &[i64], score: f64, gen_len: usize) {
        let tokens = &tokens_with_bos[1..];
        if self.finished.iter().any(|(h, _)| h.tokens == tokens) {
            return;
        }
        let norm = score / gen_len.max(1) as f64;
        self.finished.push((
            Hypothesis {
                tokens: tokens.to_vec(),
                score,
            },
            norm,
        ));
    }

    #[allow(dead_code)]
    pub fn n_finished(&self) -> usize {
        self.finished.len()
    }

    /// Whether a hypothesis with these generated tokens (no BOS/EOS) is
    /// already pooled.
    pub fn contains(&self, tokens_with_bos: &[i64]) -> bool {
        let tokens = &tokens_with_bos[1..];
        self.finished.iter().any(|(h, _)| h.tokens == tokens)
    }

    /// Stopping rule (OpenNMT/GNMT-style): `n` finished hypotheses exist
    /// and the best live beam's normalized score does not beat the worst
    /// of the top-n finished ones. (With length normalization a live
    /// beam's norm can still improve slightly, so this is the standard
    /// practical heuristic rather than a hard bound — both `beam_search`
    /// and `sbs` use it identically, which is what Table 4 needs.)
    pub fn can_stop(&self, best_live_norm: f64) -> bool {
        if self.finished.len() < self.n {
            return false;
        }
        let mut norms: Vec<f64> = self.finished.iter().map(|f| f.1).collect();
        norms.sort_by(|a, b| b.partial_cmp(a).unwrap());
        best_live_norm <= norms[self.n - 1]
    }

    /// Best-first by normalized score, deterministic tie-break.
    pub fn sorted(mut self) -> Vec<Hypothesis> {
        self.finished.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap()
                .then_with(|| a.0.tokens.cmp(&b.0.tokens))
        });
        self.finished.truncate(self.n);
        self.finished.into_iter().map(|(h, _)| h).collect()
    }
}

/// Standard beam search with beam width (and number of returned
/// hypotheses) `n`, on an incremental session.
///
/// Each surviving candidate is a [`fork`](super::DecoderSession::fork)
/// of its parent's session row extended by one token, so a KV-cached
/// backend computes exactly one position per beam per step.
pub fn beam_search<B: Backend>(backend: &B, src: &[i64], n: usize) -> Result<DecodeOutput> {
    let t0 = Instant::now();
    let ph0 = trace::thread_phase_ns();
    let dims = backend.dims();
    let memory = {
        let _enc = trace_span!(Phase::Encode, 1);
        backend.encode(&[src])?
    };
    let mut sess = {
        let _beg = trace_span!(Phase::SessionBegin);
        backend.begin(memory)?
    };
    let mut stats = DecodeStats {
        encoder_calls: 1,
        ..Default::default()
    };

    struct Live {
        state: BeamState,
        row: usize,
        sess_len: usize,
    }

    let root = sess.new_row(0);
    let mut beams = vec![Live {
        state: BeamState {
            tokens: vec![BOS_ID],
            score: 0.0,
        },
        row: root,
        sess_len: 0,
    }];
    let mut pool = BeamPool::new(n);

    while !beams.is_empty() {
        // One decoder call over every beam's pending suffix (BOS on the
        // first iteration, the single fresh token afterwards).
        let deltas: Vec<(usize, &[i64])> = beams
            .iter()
            .map(|b| (b.row, &b.state.tokens[b.sess_len..]))
            .collect();
        crate::faults::fire("decoder.extend")?;
        let lp = {
            let _ext = trace_span!(Phase::Extend, deltas.len() as u64);
            sess.extend(&deltas)?
        };
        stats.decoder_calls += 1;
        stats.decoder_rows += deltas.len();
        drop(deltas);
        for b in beams.iter_mut() {
            b.sess_len = b.state.tokens.len();
        }

        // Expand every live beam by its top-n successors.
        let mut candidates: Vec<(BeamState, usize)> = Vec::with_capacity(beams.len() * n);
        for (i, b) in beams.iter().enumerate() {
            let j = b.state.tokens.len() - 1;
            for (tok, logp) in lp.topk(i, j, n) {
                if tok == BOS_ID || tok == crate::vocab::PAD_ID {
                    continue; // structural tokens never extend a hypothesis
                }
                let mut tokens = b.state.tokens.clone();
                tokens.push(tok);
                candidates.push((
                    BeamState {
                        tokens,
                        score: b.state.score + logp as f64,
                    },
                    i,
                ));
            }
        }
        rank_by(&mut candidates, |c| &c.0);
        candidates.truncate(n);

        let mut next: Vec<Live> = Vec::with_capacity(n);
        for (c, pi) in candidates {
            let gen_len = c.tokens.len() - 1;
            if *c.tokens.last().unwrap() == EOS_ID {
                pool.push_finished(&c.tokens[..c.tokens.len() - 1], c.score, gen_len);
            } else if c.tokens.len() >= dims.t_len {
                // Window exhausted: retire as-is (no EOS).
                pool.push_finished(&c.tokens, c.score, gen_len);
            } else {
                let row = sess.fork(beams[pi].row);
                next.push(Live {
                    state: c,
                    row,
                    sess_len: beams[pi].sess_len,
                });
            }
        }
        // Parents are superseded by their forks.
        for b in &beams {
            sess.release(b.row);
        }
        beams = next;
        let best_live_norm = beams
            .first()
            .map(|b| b.state.norm())
            .unwrap_or(f64::NEG_INFINITY);
        if pool.can_stop(best_live_norm) {
            break;
        }
    }

    stats.absorb_session(&sess.stats());
    stats.wall = t0.elapsed();
    let ph1 = trace::thread_phase_ns();
    let phase_us = |p: Phase| ph1[p as usize].saturating_sub(ph0[p as usize]) / 1000;
    stats.encode_us = phase_us(Phase::Encode);
    stats.extend_us = phase_us(Phase::Extend);
    stats.verify_us = phase_us(Phase::Verify);
    Ok(DecodeOutput {
        hyps: pool.sorted(),
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::greedy;
    use crate::rng::Rng;
    use crate::testutil::{random_wrapped_src, rescore, CopyModel, HashModel};

    #[test]
    fn beam1_matches_greedy() {
        // Width-1 beam search must find the greedy sequence.
        let mut rng = Rng::new(21);
        for case in 0..10 {
            let m = HashModel::new(64, 64, 32, case + 100);
            let src = random_wrapped_src(&mut rng, 4, 16, 32);
            let g = greedy(&m, &src).unwrap();
            let b = beam_search(&m, &src, 1).unwrap();
            assert_eq!(b.hyps.len(), 1);
            assert_eq!(b.hyps[0].tokens, g.hyps[0].tokens, "case {case}");
            assert!((b.hyps[0].score - g.hyps[0].score).abs() < 1e-5);
        }
    }

    #[test]
    fn returns_n_sorted_distinct_hypotheses() {
        let m = HashModel::new(64, 64, 32, 9);
        let mut rng = Rng::new(33);
        let src = random_wrapped_src(&mut rng, 6, 16, 32);
        let out = beam_search(&m, &src, 5).unwrap();
        assert_eq!(out.hyps.len(), 5);
        for w in out.hyps.windows(2) {
            // Sorted by normalized score.
            let na = w[0].score / (w[0].tokens.len() + 1) as f64;
            let nb = w[1].score / (w[1].tokens.len() + 1) as f64;
            assert!(na >= nb - 1e-9, "not sorted: {na} < {nb}");
        }
        let set: std::collections::HashSet<&Vec<i64>> =
            out.hyps.iter().map(|h| &h.tokens).collect();
        assert_eq!(set.len(), 5, "duplicate hypotheses");
    }

    #[test]
    fn hypothesis_scores_are_true_model_scores() {
        let m = HashModel::new(64, 64, 32, 11);
        let mut rng = Rng::new(44);
        for _ in 0..5 {
            let src = random_wrapped_src(&mut rng, 5, 14, 32);
            let b = beam_search(&m, &src, 5).unwrap();
            for h in &b.hyps {
                let truth = rescore(&m, &src, &h.tokens, true);
                assert!((truth - h.score).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn copy_model_beam_top1_is_target() {
        let m = CopyModel::new(96, 96, 40);
        let src = vec![BOS_ID, 10, 11, 12, 13, 14, EOS_ID];
        let out = beam_search(&m, &src, 5).unwrap();
        assert_eq!(out.hyps[0].tokens, m.target_for(&src));
    }
}
