//! Kernel-layer micro benchmarks: packed-GEMM latency under both SIMD
//! dispatch levels, pool-vs-scoped-spawn threading overhead, encoder
//! cross-row packing, cross-row fused `extend` packing, and
//! synthetic-model decode throughput.
//!
//! Unlike the table/figure benches this needs **no data or artifacts** —
//! everything runs against in-memory synthetic models — so it doubles as
//! the CI perf-smoke step. Flags:
//!
//! * `--smoke`  fewer samples / smaller sweeps (CI),
//! * `--json`   write/update the `BENCH_kernels.json` perf trajectory.
//!   The section name carries the active dispatch (`kernel_micro` under
//!   SIMD, `kernel_micro_scalar` when `RXNSPEC_SIMD=off` forces the
//!   portable fallback), so CI can record both paths in one artifact;
//!   the GEMM sweep additionally measures both levels explicitly per
//!   shape (`*_gflops` = portable fallback, `*_simd_gflops` = detected
//!   SIMD backend).

use std::time::Instant;

use rxnspec::bench::{bench_json_path, json, json_flag, measure, report};
use rxnspec::cache::ArenaCounters;
use rxnspec::decoding::{
    greedy_batch, spec_greedy_batch, ArenaConfig, Backend, DecoderSession, SessionStats,
};
use rxnspec::draft::DraftConfig;
use rxnspec::kernels::simd::{simd_level, SimdLevel};
use rxnspec::kernels::{threads, PackedLinear};
use rxnspec::model::Config;
use rxnspec::rng::Rng;
use rxnspec::testutil::{
    random_rust_backend_cfg, random_wrapped_src, DeccacheHarness, ForceStateless,
};

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let emit_json = json_flag();
    let samples = if smoke { 2 } else { 5 };
    let mut entries: Vec<(String, json::Val)> = Vec::new();
    let mut rows = Vec::new();
    let mut rng = Rng::new(0xBE7C);
    let level = simd_level();
    eprintln!("simd dispatch: {}", level.name());
    if level == SimdLevel::Scalar && !rxnspec::knobs::SIMD.is_set() {
        // Not forced off, yet detection came up empty: the run will
        // record the `kernel_micro_scalar` section and no SIMD numbers
        // will exist in the artifact. Say so loudly instead of letting
        // the trajectory silently look like a partial run.
        eprintln!(
            "warning: CPU reports no avx2+fma — recording scalar-fallback \
             numbers only (section kernel_micro_scalar)"
        );
    }
    entries.push(("simd_level".into(), json::Val::str(level.name())));

    // --- packed GEMM latency sweep, both dispatch levels ---------------
    // (n, din, dout): a batched layer pass, a single-row layer pass, and
    // an output-head-shaped tall GEMM.
    let shapes = [(32usize, 256usize, 256usize), (1, 256, 256), (8, 256, 1024)];
    let iters = if smoke { 20 } else { 200 };
    for &(n, din, dout) in &shapes {
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let x = rand_vec(&mut rng, n * din);
        let packed = PackedLinear::pack(&w, din, dout, &b);
        let mut y = vec![0f32; n * dout];
        let levels: &[SimdLevel] = if level == SimdLevel::Scalar {
            &[SimdLevel::Scalar]
        } else {
            &[SimdLevel::Scalar, SimdLevel::Avx2]
        };
        for &lv in levels {
            let mut sink = 0f32;
            let label = format!("gemm {n}x{din}x{dout} [{}]", lv.name());
            let m = measure(&label, 1, samples, || {
                for _ in 0..iters {
                    packed.apply_into_with(&x, n, &mut y, 1, lv);
                    sink += y[0];
                }
                vec![("iters".into(), iters as f64)]
            });
            let ns_per = m.mean_s() * 1e9 / iters as f64;
            let gflops = (2.0 * n as f64 * din as f64 * dout as f64 * iters as f64)
                / (m.mean_s() * 1e9);
            eprintln!("  {label}: {ns_per:.0} ns/GEMM, {gflops:.2} GFLOP/s (sink {sink:.1})");
            let suffix = match lv {
                SimdLevel::Scalar => "",
                SimdLevel::Avx2 => "_simd",
            };
            entries.push((
                format!("gemm_{n}x{din}x{dout}{suffix}_ns"),
                json::Val::num(ns_per),
            ));
            entries.push((
                format!("gemm_{n}x{din}x{dout}{suffix}_gflops"),
                json::Val::num(gflops),
            ));
            rows.push(m);
        }
    }

    // --- pool vs scoped-spawn dispatch overhead ------------------------
    // Trivial per-item work over a handful of chunks: what's measured is
    // the fork/join round trip itself, the cost the adaptive
    // `par_min_macs` gate amortizes.
    {
        let disp_iters = if smoke { 50 } else { 300 };
        let n_items = 8usize;
        let m_pool = measure("dispatch pool (8 chunks)", 1, samples, || {
            let mut items = vec![0u64; n_items];
            for _ in 0..disp_iters {
                threads::for_each_partitioned(&mut items, n_items, |x| {
                    *x = x.wrapping_add(1)
                });
            }
            vec![("iters".into(), disp_iters as f64)]
        });
        let pool_ns = m_pool.mean_s() * 1e9 / disp_iters as f64;
        rows.push(m_pool);
        let m_spawn = measure("dispatch scoped-spawn (8 chunks)", 1, samples, || {
            let mut items = vec![0u64; n_items];
            for _ in 0..disp_iters {
                threads::for_each_partitioned_scoped(&mut items, n_items, |x| {
                    *x = x.wrapping_add(1)
                });
            }
            vec![("iters".into(), disp_iters as f64)]
        });
        let spawn_ns = m_spawn.mean_s() * 1e9 / disp_iters as f64;
        rows.push(m_spawn);
        eprintln!(
            "  dispatch: pool {pool_ns:.0} ns vs scoped-spawn {spawn_ns:.0} ns \
             ({:.1}x), cold-measured pool dispatch {} ns, gate {} MACs",
            spawn_ns / pool_ns.max(1.0),
            threads::pool_dispatch_ns(),
            threads::par_min_macs(),
        );
        entries.push((
            "pool_dispatch_ns".into(),
            json::Val::num(threads::pool_dispatch_ns() as f64),
        ));
        entries.push(("pool_dispatch_hot_ns".into(), json::Val::num(pool_ns)));
        entries.push(("spawn_dispatch_ns".into(), json::Val::num(spawn_ns)));
        entries.push((
            "par_min_macs".into(),
            json::Val::num(threads::par_min_macs() as f64),
        ));
    }

    // --- synthetic-model decode throughput -----------------------------
    let cfg = Config {
        vocab: 48,
        d_model: 64,
        n_heads: 4,
        d_ff: 128,
        n_enc: 2,
        n_dec: 2,
        s_len: 48,
        t_len: 48,
    };
    let backend = random_rust_backend_cfg(7, cfg);
    let n_q = if smoke { 4 } else { 16 };
    let srcs: Vec<Vec<i64>> = (0..n_q)
        .map(|_| random_wrapped_src(&mut rng, 10, 28, cfg.vocab))
        .collect();
    let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();

    let mut toks = 0usize;
    let mut computed = 0usize;
    let m = measure("greedy (KV-cached)", 0, samples, || {
        toks = 0;
        computed = 0;
        for s in &refs {
            let out = greedy_batch(&backend, &[s]).unwrap();
            toks += out[0].hyps[0].tokens.len() + 1;
            computed += out[0].stats.tokens_computed;
        }
        vec![("tokens".into(), toks as f64)]
    });
    let greedy_tok_s = toks as f64 / m.mean_s();
    let recomp_tok = computed as f64 / toks.max(1) as f64;
    entries.push(("greedy_tok_s".into(), json::Val::num(greedy_tok_s)));
    entries.push(("greedy_recomp_tok".into(), json::Val::num(recomp_tok)));
    rows.push(m);

    let m = measure("greedy (stateless)", 0, samples, || {
        let nocache = ForceStateless(&backend);
        let mut t = 0usize;
        for s in &refs {
            let out = greedy_batch(&nocache, &[s]).unwrap();
            t += out[0].hyps[0].tokens.len() + 1;
        }
        vec![("tokens".into(), t as f64)]
    });
    entries.push((
        "stateless_tok_s".into(),
        json::Val::num(toks as f64 / m.mean_s()),
    ));
    rows.push(m);

    let cfg_dl = DraftConfig::new(8);
    let m = measure("spec-greedy (DL=8)", 0, samples, || {
        let mut t = 0usize;
        for s in &refs {
            let out = spec_greedy_batch(&backend, &[s], &cfg_dl).unwrap();
            t += out[0].hyps[0].tokens.len() + 1;
        }
        vec![("tokens".into(), t as f64)]
    });
    entries.push((
        "spec_dl8_tok_s".into(),
        json::Val::num(toks as f64 / m.mean_s()),
    ));
    rows.push(m);

    // --- PJRT deccache session vs stateless fallback -------------------
    // The same greedy traffic driven through the PJRT cached-session
    // machinery (runtime::deccache::CachedPjrtSession) with the
    // reference-kernel executor standing in for compiled artifacts: the
    // recomp_tok pair records the ~L/2 → ~1 win the deccache artifacts
    // buy the artifact backend (and what the no-artifact fallback pays).
    {
        let harness = DeccacheHarness::new(&backend);
        let mut dc_toks = 0usize;
        let mut dc_comp = 0usize;
        let m = measure("pjrt deccache greedy (mock exec)", 0, samples, || {
            dc_toks = 0;
            dc_comp = 0;
            for s in &refs {
                let out = greedy_batch(&harness, &[s]).unwrap();
                dc_toks += out[0].hyps[0].tokens.len() + 1;
                dc_comp += out[0].stats.tokens_computed;
            }
            vec![("tokens".into(), dc_toks as f64)]
        });
        let session_recomp = dc_comp as f64 / dc_toks.max(1) as f64;
        rows.push(m);
        let mut fb_toks = 0usize;
        let mut fb_comp = 0usize;
        let m = measure("pjrt fallback greedy (stateless)", 0, samples, || {
            let fallback = ForceStateless(&harness);
            fb_toks = 0;
            fb_comp = 0;
            for s in &refs {
                let out = greedy_batch(&fallback, &[s]).unwrap();
                fb_toks += out[0].hyps[0].tokens.len() + 1;
                fb_comp += out[0].stats.tokens_computed;
            }
            vec![("tokens".into(), fb_toks as f64)]
        });
        let fallback_recomp = fb_comp as f64 / fb_toks.max(1) as f64;
        rows.push(m);
        eprintln!(
            "  pjrt session recomp_tok {session_recomp:.2} vs stateless fallback \
             {fallback_recomp:.2} ({:.1}x fewer positions per token)",
            fallback_recomp / session_recomp.max(1e-9)
        );
        entries.push((
            "pjrt_session_recomp_tok".into(),
            json::Val::num(session_recomp),
        ));
        entries.push((
            "pjrt_fallback_recomp_tok".into(),
            json::Val::num(fallback_recomp),
        ));
    }

    // --- encoder cross-row packing -------------------------------------
    let lanes = 8usize.min(refs.len());
    let src_tokens: usize = refs[..lanes].iter().map(|s| s.len()).sum();
    let enc_iters = if smoke { 4 } else { 16 };
    let m_b = measure("encode (batched)", 1, samples, || {
        for _ in 0..enc_iters {
            let _ = backend.encode(&refs[..lanes]).unwrap();
        }
        vec![("src_tokens".into(), (src_tokens * enc_iters) as f64)]
    });
    let enc_batched_tok_s = (src_tokens * enc_iters) as f64 / m_b.mean_s();
    let m_p = measure("encode (per-row)", 1, samples, || {
        for _ in 0..enc_iters {
            for s in &refs[..lanes] {
                let _ = backend.encode(&[s]).unwrap();
            }
        }
        vec![("src_tokens".into(), (src_tokens * enc_iters) as f64)]
    });
    let enc_per_row_tok_s = (src_tokens * enc_iters) as f64 / m_p.mean_s();
    eprintln!(
        "  encode: batched {enc_batched_tok_s:.0} src-tok/s vs per-row \
         {enc_per_row_tok_s:.0} src-tok/s over {lanes} rows"
    );
    entries.push(("encode_src_tok_s".into(), json::Val::num(enc_batched_tok_s)));
    entries.push((
        "encode_per_row_src_tok_s".into(),
        json::Val::num(enc_per_row_tok_s),
    ));
    rows.push(m_b);
    rows.push(m_p);

    // --- cross-row fused extend: packed rows per call ------------------
    let memory = backend.encode(&refs[..lanes])?;
    let mut sess = backend.begin_cached(memory);
    let mut srows = Vec::new();
    for i in 0..lanes {
        srows.push(sess.new_row(i));
    }
    // Mixed window lengths per tick, like a spec-greedy batch.
    let t0 = Instant::now();
    let steps = if smoke { 8 } else { 32 };
    for step in 0..steps {
        let deltas: Vec<(usize, &[i64])> = srows
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                let w = 1 + (step + i) % 3;
                (r, &srcs[i][..w.min(srcs[i].len())])
            })
            .filter(|&(_, t)| !t.is_empty())
            .collect();
        // Roll back so the bucket never overflows across steps.
        for &(r, _) in &deltas {
            let keep = sess.row_len(r).min(4);
            sess.truncate(r, keep);
        }
        sess.extend(&deltas)?;
    }
    let fused_wall = t0.elapsed();
    let st = sess.stats();
    let rows_per_call = st.packed_rows as f64 / st.extend_calls.max(1) as f64;
    let src_rows_per_call = st.packed_src_rows as f64 / st.encode_calls.max(1) as f64;
    eprintln!(
        "  fused extend: {} calls, {} rows packed ({rows_per_call:.2} rows/call), \
         encoder {src_rows_per_call:.2} src rows/call, \
         lp high-water {} positions, {:.3}s",
        st.extend_calls,
        st.packed_rows,
        st.lp_high_water,
        fused_wall.as_secs_f64()
    );
    entries.push(("packed_rows_per_call".into(), json::Val::num(rows_per_call)));
    entries.push((
        "packed_src_rows_per_call".into(),
        json::Val::num(src_rows_per_call),
    ));
    entries.push((
        "lp_high_water".into(),
        json::Val::num(st.lp_high_water as f64),
    ));

    // --- paged KV arena: fork / truncate / heal --------------------------
    // A 32-row SBS-style fork storm off a 40-token prefix. Dense forks
    // are O(1) Arc shares that pay a **full RowCache clone** (every
    // layer's K/V mirror) on the first divergent write; paged forks pay
    // one page-table clone plus a single COW'd tail page. The headline
    // invariant — paged bytes copied per fork strictly below the dense
    // per-fork row bytes — is asserted, not just recorded.
    {
        let n_forks = 32usize;
        let prefix: Vec<i64> = (0..40u64).map(|i| 2 + (i % 40) as i64).collect();
        let one = [3i64];
        let storm_iters = if smoke { 2 } else { 8 };
        let arena_cfg = ArenaConfig::default();

        let mut run_storm = |paged: bool| -> anyhow::Result<(f64, SessionStats)> {
            let mut wall = 0f64;
            let mut last = SessionStats::default();
            for _ in 0..storm_iters {
                let mut sess = backend
                    .begin_cached_with(backend.encode(&[refs[0]])?, paged.then_some(arena_cfg));
                let root = sess.new_row(0);
                sess.extend(&[(root, &prefix)])?;
                let t0 = Instant::now();
                let forks: Vec<usize> = (0..n_forks).map(|_| sess.fork(root)).collect();
                let deltas: Vec<(usize, &[i64])> =
                    forks.iter().map(|&f| (f, one.as_slice())).collect();
                sess.extend(&deltas)?;
                wall += t0.elapsed().as_secs_f64();
                last = sess.stats();
            }
            Ok((wall / storm_iters as f64, last))
        };
        let (dense_s, _) = run_storm(false)?;
        let (paged_s, pst) = run_storm(true)?;
        // Dense divergence clones both K/V mirrors across every layer.
        let dense_bytes_per_fork = (2 * cfg.n_dec * prefix.len() * cfg.d_model * 4) as f64;
        let paged_bytes_per_fork =
            pst.fork_pages_copied as f64 * pst.kv_page_bytes as f64 / n_forks as f64;
        let peak_kv_bytes = (pst.kv_pages_high_water * pst.kv_page_bytes) as f64;
        assert!(
            paged_bytes_per_fork < dense_bytes_per_fork,
            "COW fork must copy less than a dense row clone: {paged_bytes_per_fork} vs \
             {dense_bytes_per_fork}"
        );
        eprintln!(
            "  fork storm ({n_forks} rows): dense {:.0} µs vs paged {:.0} µs, \
             {paged_bytes_per_fork:.0} B/fork copied vs dense {dense_bytes_per_fork:.0} B/fork, \
             {} pages resident (peak {peak_kv_bytes:.0} B)",
            dense_s * 1e6,
            paged_s * 1e6,
            pst.kv_pages_resident,
        );
        entries.push(("fork_storm_dense_us".into(), json::Val::num(dense_s * 1e6)));
        entries.push(("fork_storm_paged_us".into(), json::Val::num(paged_s * 1e6)));
        entries.push((
            "fork_dense_bytes_per_fork".into(),
            json::Val::num(dense_bytes_per_fork),
        ));
        entries.push((
            "fork_paged_bytes_per_fork".into(),
            json::Val::num(paged_bytes_per_fork),
        ));

        // Eviction + rehydration under a one-page budget: two rows
        // alternating extends perpetually evict each other; every evicted
        // extend heals by exact recompute (deep-rewind path).
        let starved = ArenaConfig {
            page_positions: arena_cfg.page_positions,
            budget_bytes: Some(1),
        };
        let heal_steps = if smoke { 4usize } else { 7 };
        let mut sess = backend.begin_cached_with(backend.encode(&[refs[0]])?, Some(starved));
        let a = sess.new_row(0);
        let b = sess.new_row(0);
        let t0 = Instant::now();
        for step in 0..heal_steps {
            let toks: Vec<i64> = (0..3).map(|i| 2 + ((step * 3 + i) % 37) as i64).collect();
            sess.extend(&[(a, &toks)])?;
            sess.extend(&[(b, &toks)])?;
        }
        let heal_wall = t0.elapsed().as_secs_f64();
        let hst = sess.arena_stats().expect("starved session is paged");
        eprintln!(
            "  heal (1-page budget, {heal_steps}x2 extends): {} evictions, \
             {} pages rehydrated, {:.0} µs",
            hst.evictions,
            hst.rehydrated_pages,
            heal_wall * 1e6,
        );
        // One snapshot struct renders every arena counter key — the same
        // `ArenaCounters` the STATS line and serving metrics use. Fork
        // residency comes from the storm session, eviction/heal counts
        // from the starved one.
        let mut ac = ArenaCounters::from_session(&pst);
        ac.arena_evictions = hst.evictions as u64;
        ac.rehydrated_pages = hst.rehydrated_pages as u64;
        for (k, v) in ac.bench_entries() {
            entries.push((k.into(), json::Val::num(v)));
        }
    }

    // --- trace layer: enabled-run overhead + smoke export --------------
    // Measures the same KV-cached greedy traffic with the span collector
    // off and on (the off-path cost is one relaxed atomic load per span
    // site) and, under --json, writes the captured spans next to
    // BENCH_kernels.json as Perfetto-loadable trace_smoke.json.
    {
        let trace_iters = if smoke { 2 } else { 6 };
        rxnspec::trace::set_enabled(false);
        let m_off = measure("greedy (trace off)", 0, samples, || {
            for _ in 0..trace_iters {
                for s in &refs {
                    let _ = greedy_batch(&backend, &[s]).unwrap();
                }
            }
            vec![("iters".into(), trace_iters as f64)]
        });
        rxnspec::trace::set_enabled(true);
        rxnspec::trace::clear();
        let m_on = measure("greedy (trace on)", 0, samples, || {
            for _ in 0..trace_iters {
                for s in &refs {
                    let _ = greedy_batch(&backend, &[s]).unwrap();
                }
            }
            vec![("iters".into(), trace_iters as f64)]
        });
        let overhead_pct = (m_on.mean_s() / m_off.mean_s() - 1.0) * 100.0;
        let spans = rxnspec::trace::snapshot_events().len();
        eprintln!(
            "  trace: on/off overhead {overhead_pct:+.2}% \
             ({spans} spans captured, {} dropped)",
            rxnspec::trace::dropped_events()
        );
        entries.push(("trace_overhead_pct".into(), json::Val::num(overhead_pct)));
        entries.push(("trace_spans_captured".into(), json::Val::num(spans as f64)));
        if emit_json {
            let trace_path = bench_json_path().with_file_name("trace_smoke.json");
            let out = rxnspec::trace::export_chrome_json();
            // The smoke artifact must itself be valid trace JSON: parse
            // it back and check the event array before writing.
            let parsed = json::parse(&out).expect("trace smoke export must parse as JSON");
            match parsed.get("traceEvents") {
                Some(json::Val::Arr(evs)) => {
                    assert!(!evs.is_empty(), "traced greedy run exported no events")
                }
                other => panic!("traceEvents missing from smoke export: {other:?}"),
            }
            std::fs::write(&trace_path, &out)?;
            println!("(wrote trace smoke to {})", trace_path.display());
        }
        rxnspec::trace::set_enabled(false);
        rxnspec::trace::clear();
        rows.push(m_off);
        rows.push(m_on);
    }

    // --- serving resilience smoke: supervised worker under chaos -------
    // A seeded fault plan (one guaranteed panic + low-rate background
    // chaos) against the queue/worker stack with tight deadlines, then a
    // dump/reload warm-boot replay. Records the resilience counters the
    // STATS line exposes so the trajectory catches containment
    // regressions, not just throughput ones.
    {
        use rxnspec::cache::{dump_to_path, load_into, ServeCache};
        use rxnspec::coordinator::{run_worker, DecodeMode, Job, Metrics, RequestQueue};
        use rxnspec::faults::{FaultKind, FaultPlan, Trigger};
        use rxnspec::vocab::Vocab;
        use std::sync::atomic::Ordering;
        use std::sync::{mpsc, Arc};
        use std::time::Duration;

        // Injected panics are this section's working fluid; keep their
        // backtraces out of the bench log, leave real panics loud.
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains("injected fault"))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.contains("injected fault"))
                })
                .unwrap_or(false);
            if !injected {
                hook(info);
            }
        }));

        let vocab = Vocab::build(["CCONF", "c1ccccc1Br"])?;
        let queries = ["CCO", "c1ccccc1", "NCCO", "BrCC", "FC", "c1ccccc1Br"];
        let mode_for = |round: usize, i: usize| match (round + i) % 3 {
            0 => DecodeMode::Greedy,
            1 => DecodeMode::SpecGreedy { dl: 4 },
            _ => DecodeMode::Beam { n: 2 },
        };
        let n_rounds = if smoke { 2 } else { 6 };
        rxnspec::faults::install(
            FaultPlan::new(0xBE7C)
                .with("decoder.extend", FaultKind::Panic, Trigger::Nth(3))
                .with("decoder.extend", FaultKind::Panic, Trigger::Prob(0.02))
                .with("decoder.extend", FaultKind::Slow(1), Trigger::Prob(0.02)),
        );
        let queue: RequestQueue<Job> =
            RequestQueue::with_capacity(4, Duration::from_millis(1), 16);
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();
        cache.bind_artifact_version(0xBE7C);
        let mut rxs = Vec::new();
        let mut busy = 0usize;
        let mut n_sent = 0usize;
        for round in 0..n_rounds {
            for (i, q) in queries.iter().enumerate() {
                let (tx, rx) = mpsc::channel();
                n_sent += 1;
                // Every third request carries an already-expired deadline:
                // it must be shed at pop time, never decoded.
                let deadline = (i % 3 == 2).then(Instant::now);
                let job = Job::new(q.to_string(), tx);
                match queue.try_push(mode_for(round, i), job, deadline) {
                    Ok(()) => rxs.push(rx),
                    Err(_) => busy += 1,
                }
            }
        }
        queue.close();
        let t0 = Instant::now();
        run_worker(&backend, &vocab, &queue, &metrics, &cache);
        let drain_ms = t0.elapsed().as_secs_f64() * 1e3;
        rxnspec::faults::disarm();
        let served = rxs
            .iter()
            .filter(|rx| matches!(rx.try_recv(), Ok(Ok(_))))
            .count();
        let shed = metrics.requests_shed.load(Ordering::Relaxed);
        let retried = metrics.requests_retried.load(Ordering::Relaxed);
        let contained = metrics.panics_contained.load(Ordering::Relaxed);
        let degraded = metrics.degraded_ticks.load(Ordering::Relaxed);
        assert!(contained >= 1, "the Nth(3) panic rule must be contained");
        assert!(served > 0, "chaos must not wipe out the whole workload");

        // Kill-and-restart: persist the survivors' cache pair, reload it
        // into a fresh process-worth of state, replay one clean round.
        let dump = std::env::temp_dir()
            .join(format!("rxnspec-bench-{}-resil.dump", std::process::id()));
        dump_to_path(&cache, &dump)?;
        let cache2 = ServeCache::default();
        cache2.bind_artifact_version(0xBE7C);
        let restored = load_into(&cache2, &dump, 0xBE7C)?;
        let queue2: RequestQueue<Job> = RequestQueue::new(4, Duration::from_millis(1));
        let metrics2 = Arc::new(Metrics::default());
        let mut rxs2 = Vec::new();
        for (i, q) in queries.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let job = Job::new(q.to_string(), tx);
            queue2.push(mode_for(0, i), job);
            rxs2.push(rx);
        }
        queue2.close();
        run_worker(&backend, &vocab, &queue2, &metrics2, &cache2);
        let warm_hits = cache2.results().stats().warm_hits;
        std::fs::remove_file(&dump).ok();

        eprintln!(
            "  resilience: {served}/{n_sent} served under chaos \
             ({contained} panics contained, {retried} retried, {shed} shed, \
             {busy} busy, {degraded} degraded ticks), drain {drain_ms:.1} ms, \
             warm boot restored {} results → {warm_hits} warm hits",
            restored.results,
        );
        entries.push(("resil_requests".into(), json::Val::num(n_sent as f64)));
        entries.push(("resil_served_ok".into(), json::Val::num(served as f64)));
        entries.push(("resil_requests_shed".into(), json::Val::num(shed as f64)));
        entries.push(("resil_requests_busy".into(), json::Val::num(busy as f64)));
        entries.push(("resil_requests_retried".into(), json::Val::num(retried as f64)));
        entries.push((
            "resil_panics_contained".into(),
            json::Val::num(contained as f64),
        ));
        entries.push(("resil_degraded_ticks".into(), json::Val::num(degraded as f64)));
        entries.push(("resil_drain_ms".into(), json::Val::num(drain_ms)));
        entries.push(("resil_warm_hits".into(), json::Val::num(warm_hits as f64)));
    }

    report(
        "kernel_micro",
        "Kernel layer — SIMD GEMM / pool dispatch / packed encode / fused extend",
        &rows,
    );
    println!(
        "\ngreedy {greedy_tok_s:.1} tok/s (recomp_tok {recomp_tok:.2}), \
         packed {rows_per_call:.2} rows/extend-call, \
         {src_rows_per_call:.2} src rows/encode-call [{}]",
        level.name()
    );

    if emit_json {
        let path = bench_json_path();
        // Section name carries the dispatch level AND the arena mode the
        // env-driven sessions above ran under, so CI's RXNSPEC_ARENA=off
        // smoke leg records its own trajectory instead of clobbering the
        // paged one.
        let mut section = match level {
            SimdLevel::Scalar => "kernel_micro_scalar".to_string(),
            SimdLevel::Avx2 => "kernel_micro".to_string(),
        };
        if ArenaConfig::from_env().is_none() {
            section.push_str("_arena_off");
        }
        json::merge_section(&path, &section, json::Val::obj(entries))?;
        println!("(updated {} section {section})", path.display());
    }
    Ok(())
}
