//! Figure 2 reproduction: query-copy drafting and its acceptance rate.
//!
//! The paper walks one Boc-protection reaction through the drafting
//! procedure (78% acceptance on that example; 79% corpus average at
//! DL=10 on USPTO-MIT). This bench regenerates both: the worked example,
//! and an acceptance-rate / calls-per-token sweep over draft length on a
//! corpus subset — the curve behind the Table 2 speedups.

use rxnspec::bench::{eval_setup, limit, report, Measurement};
use rxnspec::chem::tokenize;
use rxnspec::decoding::spec_greedy;
use rxnspec::draft::{extract_drafts, Acceptance, DraftConfig};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
    let n_q = limit(40).min(split.len());

    // --- the worked Figure 2 example -----------------------------------
    let reactants = "c1c[nH]c2ccc(C(C)=O)cc12.C(=O)(OC(=O)OC(C)(C)C)OC(C)(C)C";
    println!("Figure 2 example: {reactants}");
    let ids = vocab.encode(reactants)?;
    let drafts = extract_drafts(
        &ids,
        &DraftConfig {
            max_drafts: usize::MAX,
            dedup: false,
            ..DraftConfig::new(4)
        },
    );
    println!(
        "  {} query tokens -> {} drafts of length 4 (stride 1)",
        tokenize(reactants)?.len(),
        drafts.len()
    );
    let src = vocab.encode_wrapped(reactants)?;
    let out = spec_greedy(&backend, &src, &DraftConfig::new(4))?;
    println!(
        "  product: {}",
        vocab.decode(&out.hyps[0].tokens)
    );
    println!(
        "  acceptance rate {:.0}% (paper example: 78%), {} calls for {} tokens\n",
        out.stats.acceptance.rate() * 100.0,
        out.stats.decoder_calls,
        out.hyps[0].tokens.len() + 1,
    );

    // --- corpus sweep: acceptance & calls/token vs draft length --------
    let srcs: Vec<Vec<i64>> = split[..n_q]
        .iter()
        .map(|e| vocab.encode_wrapped(&e.src))
        .collect::<Result<_, _>>()?;
    let mut rows = Vec::new();
    for dl in [1usize, 2, 4, 6, 8, 10, 12] {
        let cfg = DraftConfig::new(dl);
        let mut acc = Acceptance::default();
        let mut calls = 0usize;
        let mut toks = 0usize;
        let t0 = Instant::now();
        for s in &srcs {
            let out = spec_greedy(&backend, s, &cfg)?;
            acc.merge(&out.stats.acceptance);
            calls += out.stats.decoder_calls;
            toks += out.hyps[0].tokens.len() + 1;
        }
        let wall = t0.elapsed();
        eprintln!(
            "  DL={dl:<2} acc={:.2} tokens/call={:.2}",
            acc.rate(),
            toks as f64 / calls as f64
        );
        rows.push(Measurement {
            label: format!("DL={dl}"),
            samples: vec![wall],
            aux: vec![
                ("acceptance".into(), acc.rate()),
                ("tokens_per_call".into(), toks as f64 / calls as f64),
                ("calls".into(), calls as f64),
            ],
        });
    }
    report(
        "fig2_acceptance",
        "Figure 2 — acceptance rate vs draft length (fwd subset)",
        &rows,
    );
    println!("\npaper reference: 79% average acceptance at DL=10 on USPTO-MIT");
    Ok(())
}
