//! Figure 2 reproduction: query-copy drafting and its acceptance rate.
//!
//! The paper walks one Boc-protection reaction through the drafting
//! procedure (78% acceptance on that example; 79% corpus average at
//! DL=10 on USPTO-MIT). This bench regenerates both: the worked example,
//! and an acceptance-rate / calls-per-token sweep over draft length on a
//! corpus subset — the curve behind the Table 2 speedups.
//!
//! The sweep additionally runs through the cache subsystem: each query
//! passes twice over a `ResultCache` (the repeat pass measures the hit
//! rate on recurring traffic) while a `DraftStore` warms online from the
//! produced targets, so acceptance splits into query-copy vs
//! corpus-learned draft sources (`acc_query` / `acc_corpus` columns).

use rxnspec::bench::{bench_json_path, eval_setup, json, json_flag, limit, report, Measurement};
use rxnspec::cache::{DraftStore, ResultCache};
use rxnspec::chem::tokenize;
use rxnspec::decoding::{spec_greedy, spec_greedy_corpus};
use rxnspec::draft::{extract_drafts, Acceptance, DraftConfig};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
    let n_q = limit(40).min(split.len());

    // --- the worked Figure 2 example -----------------------------------
    let reactants = "c1c[nH]c2ccc(C(C)=O)cc12.C(=O)(OC(=O)OC(C)(C)C)OC(C)(C)C";
    println!("Figure 2 example: {reactants}");
    let ids = vocab.encode(reactants)?;
    let drafts = extract_drafts(
        &ids,
        &DraftConfig {
            max_drafts: usize::MAX,
            dedup: false,
            ..DraftConfig::new(4)
        },
    );
    println!(
        "  {} query tokens -> {} drafts of length 4 (stride 1)",
        tokenize(reactants)?.len(),
        drafts.len()
    );
    let src = vocab.encode_wrapped(reactants)?;
    let out = spec_greedy(&backend, &src, &DraftConfig::new(4))?;
    println!(
        "  product: {}",
        vocab.decode(&out.hyps[0].tokens)
    );
    println!(
        "  acceptance rate {:.0}% (paper example: 78%), {} calls for {} tokens\n",
        out.stats.acceptance.rate() * 100.0,
        out.stats.decoder_calls,
        out.hyps[0].tokens.len() + 1,
    );

    // --- corpus sweep: acceptance & calls/token vs draft length --------
    let srcs: Vec<Vec<i64>> = split[..n_q]
        .iter()
        .map(|e| vocab.encode_wrapped(&e.src))
        .collect::<Result<_, _>>()?;
    let mut rows = Vec::new();
    for dl in [1usize, 2, 4, 6, 8, 10, 12] {
        let cfg = DraftConfig::new(dl);
        // Fresh per-DL cache pair: the store warms online from produced
        // targets; the result cache serves the repeat pass.
        let store = DraftStore::new(dl.max(2), 2048);
        let rcache: ResultCache<Vec<i64>> = ResultCache::new(1024, 4);
        let mut acc = Acceptance::default();
        let (mut acc_query, mut acc_corpus) = (0usize, 0usize);
        let mut calls = 0usize;
        let mut toks = 0usize;
        let t0 = Instant::now();
        for _pass in 0..2 {
            for s in &srcs {
                // A hit is served without decoding — the stored target
                // replays verbatim (duplicate split entries may already
                // hit on the first pass).
                if rcache.get(dl as u64, s).is_some() {
                    continue;
                }
                let out = spec_greedy_corpus(&backend, s, &cfg, &store.top_k(8))?;
                acc.merge(&out.stats.acceptance);
                acc_query += out.stats.accepted_query_tokens;
                acc_corpus += out.stats.accepted_corpus_tokens;
                calls += out.stats.decoder_calls;
                toks += out.hyps[0].tokens.len() + 1;
                store.record(&out.hyps[0].tokens);
                rcache.insert(dl as u64, s.clone(), out.hyps[0].tokens.clone());
            }
        }
        let wall = t0.elapsed();
        let cs = rcache.stats();
        eprintln!(
            "  DL={dl:<2} acc={:.2} tokens/call={:.2} cache_hit_rate={:.2} corpus_share={:.3}",
            acc.rate(),
            toks as f64 / calls as f64,
            cs.hit_rate(),
            acc_corpus as f64 / (acc_query + acc_corpus).max(1) as f64,
        );
        rows.push(Measurement {
            label: format!("DL={dl}"),
            samples: vec![wall],
            aux: vec![
                ("acceptance".into(), acc.rate()),
                ("tokens_per_call".into(), toks as f64 / calls as f64),
                ("calls".into(), calls as f64),
                (
                    "acc_query".into(),
                    acc_query as f64 / acc.total_tokens.max(1) as f64,
                ),
                (
                    "acc_corpus".into(),
                    acc_corpus as f64 / acc.total_tokens.max(1) as f64,
                ),
                ("cache_hit_rate".into(), cs.hit_rate()),
            ],
        });
    }
    report(
        "fig2_acceptance",
        "Figure 2 — acceptance rate vs draft length (fwd subset)",
        &rows,
    );
    println!("\npaper reference: 79% average acceptance at DL=10 on USPTO-MIT");
    println!(
        "cache columns: acc_query/acc_corpus split total acceptance by draft source; \
         cache_hit_rate is the repeat-pass ResultCache rate (~0.5 by construction)"
    );

    // Machine-readable perf trajectory (`--json`): per-DL acceptance and
    // tokens/call merged into BENCH_kernels.json.
    if json_flag() {
        let mut entries: Vec<(String, json::Val)> = Vec::new();
        for r in &rows {
            entries.push((
                r.label.clone(),
                json::Val::obj(vec![
                    (
                        "acceptance".into(),
                        json::Val::num(r.aux_metric("acceptance")),
                    ),
                    (
                        "tokens_per_call".into(),
                        json::Val::num(r.aux_metric("tokens_per_call")),
                    ),
                ]),
            ));
        }
        let path = bench_json_path();
        json::merge_section(&path, "fig2_acceptance", json::Val::obj(entries))?;
        println!("(updated {})", path.display());
    }
    Ok(())
}
