//! `serve_load` — closed-loop load generator against a live in-process
//! server: M client threads hammering the TCP front end while a worker
//! pool drains the queue, per worker count.
//!
//! Measures what the multi-worker tier actually buys at the protocol
//! boundary (connection handling + queueing + decode included): p50/p99
//! request latency and sustained req/s for 1 worker vs the pooled
//! configuration. The backend is the deterministic CopyModel so the
//! numbers isolate the serving stack, not model FLOPs, and the cache is
//! disabled so every request is an honest decode. Flags:
//!
//! * `--smoke`  fewer clients / requests (CI),
//! * `--json`   merge results into `BENCH_kernels.json` (also via
//!   `BENCH_JSON=1`), section `serve_load`: `serve_p50_ms`,
//!   `serve_p99_ms`, `serve_rps` (pooled) plus `_w<N>`-suffixed entries
//!   per swept worker count.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use rxnspec::bench::{bench_json_path, json, json_flag};
use rxnspec::cache::ServeCache;
use rxnspec::coordinator::{
    run_pool, serve, Client, Metrics, PoolConfig, RequestQueue, ServerState,
};
use rxnspec::testutil::CopyModel;
use rxnspec::vocab::Vocab;

const QUERIES: [&str; 6] = ["CCO", "c1ccccc1", "NCCO", "BrCC", "FC", "c1ccccc1Br"];

struct LoadResult {
    p50_ms: f64,
    p99_ms: f64,
    rps: f64,
    served: usize,
}

fn quantile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted_ms.len() as f64).ceil() as usize).clamp(1, sorted_ms.len()) - 1;
    sorted_ms[idx]
}

/// One closed-loop run: a fresh server + `workers` pool, `clients`
/// threads each issuing `reqs_per_client` PREDICTs back-to-back.
fn run_load(workers: usize, clients: usize, reqs_per_client: usize) -> Result<LoadResult> {
    let vocab = Vocab::build(["CCONF", "c1ccccc1Br"]).unwrap();
    let state = Arc::new(ServerState::with_limits(
        RequestQueue::with_capacity(8, Duration::from_millis(1), 1024),
        Arc::new(Metrics::default()),
        Arc::new(ServeCache::disabled()),
        None,
        clients + 8,
    ));
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let accept_state = Arc::clone(&state);
    let acceptor = std::thread::spawn(move || serve(listener, accept_state));

    let cfg = PoolConfig::with_workers(workers);
    let n_vocab = vocab.len();
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(clients * reqs_per_client);
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let pool = s.spawn(|| {
            run_pool(
                |_slot| Ok(CopyModel::new(96, 96, n_vocab)),
                &vocab,
                &state.queue,
                &state.metrics,
                &state.cache,
                &cfg,
            )
        });
        let client_handles: Vec<_> = (0..clients)
            .map(|c| {
                let addr = addr.clone();
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut cl = Client::connect(&addr)?;
                    let mut lat = Vec::with_capacity(reqs_per_client);
                    for i in 0..reqs_per_client {
                        let q = QUERIES[(c + i) % QUERIES.len()];
                        let decoder = if (c + i) % 2 == 0 { "greedy" } else { "spec:3" };
                        let t = Instant::now();
                        let pred = cl.predict(decoder, q)?;
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                        assert!(!pred.hyps.is_empty(), "server must return a hypothesis");
                    }
                    Ok(lat)
                })
            })
            .collect();
        for h in client_handles {
            latencies_ms.extend(h.join().expect("client thread must not panic")?);
        }
        // All clients done: drain the pool so the scope can join it.
        Client::connect(&addr)?.shutdown()?;
        pool.join().expect("pool supervisor must not panic");
        Ok(())
    })?;
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = acceptor.join();

    let served = latencies_ms.len();
    latencies_ms.sort_by(|a, b| a.total_cmp(b));
    Ok(LoadResult {
        p50_ms: quantile(&latencies_ms, 0.50),
        p99_ms: quantile(&latencies_ms, 0.99),
        rps: served as f64 / wall_s.max(1e-9),
        served,
    })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let emit_json = json_flag();
    let (clients, reqs_per_client) = if smoke { (4, 24) } else { (8, 120) };
    let sweep = [1usize, 4];

    println!(
        "serve_load — {clients} clients x {reqs_per_client} reqs, worker sweep {sweep:?} \
         (CopyModel backend, cache off)"
    );
    let mut entries: Vec<(String, json::Val)> = vec![
        ("serve_clients".into(), json::Val::num(clients as f64)),
        ("serve_reqs_per_client".into(), json::Val::num(reqs_per_client as f64)),
    ];
    let mut pooled: Option<LoadResult> = None;
    for &w in &sweep {
        let r = run_load(w, clients, reqs_per_client)?;
        println!(
            "  workers={w}: p50 {:.2} ms  p99 {:.2} ms  {:.0} req/s  ({} served)",
            r.p50_ms, r.p99_ms, r.rps, r.served
        );
        assert_eq!(
            r.served,
            clients * reqs_per_client,
            "workers={w}: every request must be served"
        );
        entries.push((format!("serve_p50_ms_w{w}"), json::Val::num(r.p50_ms)));
        entries.push((format!("serve_p99_ms_w{w}"), json::Val::num(r.p99_ms)));
        entries.push((format!("serve_rps_w{w}"), json::Val::num(r.rps)));
        pooled = Some(r);
    }
    // The headline keys carry the pooled (last-swept) configuration.
    let pooled = pooled.expect("sweep is non-empty");
    let pool_workers = *sweep.last().unwrap();
    entries.push(("serve_workers".into(), json::Val::num(pool_workers as f64)));
    entries.push(("serve_p50_ms".into(), json::Val::num(pooled.p50_ms)));
    entries.push(("serve_p99_ms".into(), json::Val::num(pooled.p99_ms)));
    entries.push(("serve_rps".into(), json::Val::num(pooled.rps)));

    if emit_json {
        let path = bench_json_path();
        json::merge_section(&path, "serve_load", json::Val::obj(entries))?;
        println!("(updated {} section serve_load)", path.display());
    }
    Ok(())
}
