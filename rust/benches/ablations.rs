//! §3.3 limitation + design-choice ablations.
//!
//! (a) Large beam: the paper reports SBS *loses* to BS at beam 50 because
//!     the effective batch (beams × drafts) saturates the device and the
//!     least-lucky beam bounds the call count.
//! (b) Draft-count cap N_d: bounding drafts mitigates effective-batch
//!     inflation but costs acceptance (the §3.3 trade-off).
//! (c) Dilated drafts: the §3.1 suggestion — windows that skip one token —
//!     buys acceptance on reactions with single-token deletions.
//! (d) Batched speculation: with B>1 the least-lucky query dictates the
//!     number of calls ("the sequence with the lowest acceptance rate
//!     determines the number of calls").
//!
//! RXNSPEC_LIMIT scales the subsets (default 8).

use rxnspec::bench::{eval_setup, limit, measure, report, speedup};
use rxnspec::decoding::{beam_search, sbs, spec_greedy, spec_greedy_batch, SbsConfig};
use rxnspec::draft::{Acceptance, DraftConfig};

fn main() -> anyhow::Result<()> {
    let n_q = limit(8);

    // ---------- (a) beam-50 limitation (retro) -------------------------
    {
        let (vocab, backend, split) = eval_setup("retro")?;
    backend.precompile()?;
        let srcs: Vec<Vec<i64>> = split[..3.min(split.len())]
            .iter()
            .map(|e| vocab.encode_wrapped(&e.src))
            .collect::<Result<_, _>>()?;
        let mut rows = Vec::new();
        for &n in &[5usize, 50] {
            rows.push(measure(&format!("BS n={n}"), 0, 1, || {
                let mut calls = 0;
                for s in &srcs {
                    calls += beam_search(&backend, s, n).unwrap().stats.decoder_calls;
                }
                vec![("calls".into(), calls as f64)]
            }));
            rows.push(measure(&format!("SBS n={n} DL=10"), 0, 1, || {
                let mut calls = 0;
                for s in &srcs {
                    calls += sbs(&backend, s, &SbsConfig::new(n, 10))
                        .unwrap()
                        .stats
                        .decoder_calls;
                }
                vec![("calls".into(), calls as f64)]
            }));
        }
        report(
            "ablation_beam50",
            "§3.3 — SBS advantage collapses at large beam width",
            &rows,
        );
        println!(
            "speedup n=5: {:.2}x, n=50: {:.2}x (paper: SBS slower than BS at n=50)",
            speedup(&rows[0], &rows[1]),
            speedup(&rows[2], &rows[3]),
        );
    }

    // ---------- (b) N_d cap sweep (fwd, spec greedy) --------------------
    {
        let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
        let srcs: Vec<Vec<i64>> = split[..n_q.min(split.len())]
            .iter()
            .map(|e| vocab.encode_wrapped(&e.src))
            .collect::<Result<_, _>>()?;
        let mut rows = Vec::new();
        for &nd in &[5usize, 10, 25, 50] {
            let cfg = DraftConfig {
                max_drafts: nd,
                ..DraftConfig::new(10)
            };
            rows.push(measure(&format!("N_d={nd}"), 0, 1, || {
                let mut acc = Acceptance::default();
                let mut calls = 0;
                for s in &srcs {
                    let o = spec_greedy(&backend, s, &cfg).unwrap();
                    acc.merge(&o.stats.acceptance);
                    calls += o.stats.decoder_calls;
                }
                vec![
                    ("acceptance".into(), acc.rate()),
                    ("calls".into(), calls as f64),
                ]
            }));
        }
        report(
            "ablation_nd",
            "§3.3 — draft-count cap vs acceptance trade-off (DL=10)",
            &rows,
        );
    }

    // ---------- (c) dilated drafts (fwd) --------------------------------
    {
        let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
        let srcs: Vec<Vec<i64>> = split[..n_q.min(split.len())]
            .iter()
            .map(|e| vocab.encode_wrapped(&e.src))
            .collect::<Result<_, _>>()?;
        let mut rows = Vec::new();
        for dilated in [false, true] {
            let cfg = DraftConfig {
                dilated,
                max_drafts: 40,
                ..DraftConfig::new(10)
            };
            rows.push(measure(
                if dilated { "dilated" } else { "plain" },
                0,
                1,
                || {
                    let mut acc = Acceptance::default();
                    let mut calls = 0;
                    for s in &srcs {
                        let o = spec_greedy(&backend, s, &cfg).unwrap();
                        acc.merge(&o.stats.acceptance);
                        calls += o.stats.decoder_calls;
                    }
                    vec![
                        ("acceptance".into(), acc.rate()),
                        ("calls".into(), calls as f64),
                    ]
                },
            ));
        }
        report(
            "ablation_dilated",
            "§3.1 — dilated draft windows (deletion coverage)",
            &rows,
        );
    }

    // ---------- (d) least-lucky batching effect (fwd) -------------------
    {
        let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
        let take = (n_q.max(8)).min(split.len());
        let srcs: Vec<Vec<i64>> = split[..take]
            .iter()
            .map(|e| vocab.encode_wrapped(&e.src))
            .collect::<Result<_, _>>()?;
        let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();
        let cfg = DraftConfig::new(10);
        let solo = measure("spec B=1 xN", 0, 1, || {
            let mut calls = 0;
            for s in &refs {
                calls += spec_greedy_batch(&backend, &[s], &cfg).unwrap()[0]
                    .stats
                    .decoder_calls;
            }
            vec![("calls".into(), calls as f64)]
        });
        let batched = measure("spec B=8 batched", 0, 1, || {
            let mut calls = 0;
            for chunk in refs.chunks(8) {
                calls += spec_greedy_batch(&backend, chunk, &cfg).unwrap()[0]
                    .stats
                    .decoder_calls;
            }
            vec![("calls".into(), calls as f64)]
        });
        println!(
            "least-lucky effect: solo total calls {:.0}, batched calls {:.0} \
             (batched ≤ solo, but each call is bigger — §3.3)",
            solo.aux[0].1, batched.aux[0].1
        );
        report(
            "ablation_least_lucky",
            "§3.3 — batched speculation: least-lucky query bounds calls",
            &[solo, batched],
        );
    }

    Ok(())
}
