//! Tables 3 & 4 reproduction: single-step retrosynthesis with beam search
//! vs speculative beam search.
//!
//! Paper (USPTO-50K test, 5k reactions, H100), wall time:
//!                    n=5      n=10     n=25
//!     BS             36.7     39.9     46.2   min
//!     SBS, DL=10      9.9     15.4     28.1   min   (3.7x / 2.7x / 1.8x)
//!     SBS, DL=0      23.1     25.7     34.6   min
//! and Table 4: top-N accuracy identical between BS and SBS.
//!
//! Here: a subset of the synthetic retro split on CPU PJRT; shape under
//! reproduction: SBS(DL=10) fastest, advantage shrinking as n grows, and
//! top-N outputs matching BS. RXNSPEC_LIMIT sets the subset (default 12).

use rxnspec::bench::{eval_setup, limit, measure, report, speedup, DeviceModel, Measurement};
use rxnspec::decoding::{beam_search, sbs, DecodeStats, SbsConfig};

/// Fold one run's trace-populated phase times (µs) into `[enc, ext, ver]`.
fn phase_add(acc: [u64; 3], s: &DecodeStats) -> [u64; 3] {
    [
        acc[0] + s.encode_us,
        acc[1] + s.extend_us,
        acc[2] + s.verify_us,
    ]
}

fn main() -> anyhow::Result<()> {
    let (vocab, backend, split) = eval_setup("retro")?;
    backend.precompile()?;
    // Phase columns (enc/ext/ver) come from the trace layer; collection
    // stays on for the whole bench and never changes decoded outputs.
    rxnspec::trace::set_enabled(true);
    let n_q = limit(12).min(split.len());
    let srcs: Vec<Vec<i64>> = split[..n_q]
        .iter()
        .map(|e| vocab.encode_wrapped(&e.src))
        .collect::<Result<_, _>>()?;
    let tgts: Vec<&str> = split[..n_q].iter().map(|e| e.tgt.as_str()).collect();
    eprintln!("table3: {} retro queries", n_q);
    let dm = DeviceModel::calibrate(&backend, &vocab, &split[0].src)?;
    eprintln!("device model: {}", dm.describe());

    let widths = [5usize, 10, 25];
    let mut all_rows: Vec<Measurement> = Vec::new();
    let mut table4: Vec<(String, Vec<f64>)> = Vec::new();

    for &n in &widths {
        // Standard beam search.
        let mut bs_hyps: Vec<Vec<Vec<i64>>> = Vec::new();
        let m_bs = measure(&format!("BS n={n}"), 0, 1, || {
            let _ = backend.take_call_log();
            bs_hyps.clear();
            let mut calls = 0usize;
            let (mut computed, mut reused) = (0usize, 0usize);
            let mut ph = [0u64; 3];
            for s in &srcs {
                let out = beam_search(&backend, s, n).unwrap();
                calls += out.stats.decoder_calls;
                computed += out.stats.tokens_computed;
                reused += out.stats.tokens_reused;
                ph = phase_add(ph, &out.stats);
                bs_hyps.push(out.hyps.iter().map(|h| h.tokens.clone()).collect());
            }
            let proj = dm.project(&backend.take_call_log());
            vec![
                ("calls".into(), calls as f64),
                ("reuse".into(), reused as f64 / (computed + reused).max(1) as f64),
                ("proj_s".into(), proj),
                ("enc_ms".into(), ph[0] as f64 / 1e3),
                ("ext_ms".into(), ph[1] as f64 / 1e3),
                ("ver_ms".into(), ph[2] as f64 / 1e3),
            ]
        });

        // SBS DL=10 and the DL=0 control.
        let mut sbs_hyps: Vec<Vec<Vec<i64>>> = Vec::new();
        let m_sbs = measure(&format!("SBS n={n} DL=10"), 0, 1, || {
            let _ = backend.take_call_log();
            sbs_hyps.clear();
            let mut calls = 0usize;
            let (mut computed, mut reused) = (0usize, 0usize);
            let mut ph = [0u64; 3];
            for s in &srcs {
                let out = sbs(&backend, s, &SbsConfig::new(n, 10)).unwrap();
                calls += out.stats.decoder_calls;
                computed += out.stats.tokens_computed;
                reused += out.stats.tokens_reused;
                ph = phase_add(ph, &out.stats);
                sbs_hyps.push(out.hyps.iter().map(|h| h.tokens.clone()).collect());
            }
            let proj = dm.project(&backend.take_call_log());
            vec![
                ("calls".into(), calls as f64),
                ("reuse".into(), reused as f64 / (computed + reused).max(1) as f64),
                ("proj_s".into(), proj),
                ("enc_ms".into(), ph[0] as f64 / 1e3),
                ("ext_ms".into(), ph[1] as f64 / 1e3),
                ("ver_ms".into(), ph[2] as f64 / 1e3),
            ]
        });
        let m_sbs0 = measure(&format!("SBS n={n} DL=0"), 0, 1, || {
            let _ = backend.take_call_log();
            let mut calls = 0usize;
            let (mut computed, mut reused) = (0usize, 0usize);
            let mut ph = [0u64; 3];
            for s in &srcs {
                let out = sbs(&backend, s, &SbsConfig::new(n, 0)).unwrap();
                calls += out.stats.decoder_calls;
                computed += out.stats.tokens_computed;
                reused += out.stats.tokens_reused;
                ph = phase_add(ph, &out.stats);
            }
            let proj = dm.project(&backend.take_call_log());
            vec![
                ("calls".into(), calls as f64),
                ("reuse".into(), reused as f64 / (computed + reused).max(1) as f64),
                ("proj_s".into(), proj),
                ("enc_ms".into(), ph[0] as f64 / 1e3),
                ("ext_ms".into(), ph[1] as f64 / 1e3),
                ("ver_ms".into(), ph[2] as f64 / 1e3),
            ]
        });

        let pj = |m: &Measurement| m.aux.iter().find(|a| a.0 == "proj_s").map(|a| a.1).unwrap_or(0.0);
        println!(
            "n={n}: wall SBS(DL=10) {:.2}x / projected {:.2}x (paper {}), SBS(DL=0) {:.2}x",
            speedup(&m_bs, &m_sbs),
            pj(&m_bs) / pj(&m_sbs),
            match n {
                5 => "3.7x",
                10 => "2.7x",
                _ => "1.8x",
            },
            speedup(&m_bs, &m_sbs0),
        );

        // Table 4: top-N accuracy, BS vs SBS.
        let top_ns: Vec<usize> = [1usize, 3, 5, 10, 25].iter().copied().filter(|&k| k <= n).collect();
        let acc = |hyps: &Vec<Vec<Vec<i64>>>| -> Vec<f64> {
            top_ns
                .iter()
                .map(|&k| {
                    let hit = hyps
                        .iter()
                        .zip(&tgts)
                        .filter(|(hs, t)| {
                            hs.iter().take(k).any(|h| vocab.decode(h) == **t)
                        })
                        .count();
                    hit as f64 * 100.0 / n_q as f64
                })
                .collect()
        };
        table4.push((format!("BS n={n}"), acc(&bs_hyps)));
        table4.push((format!("SBS n={n} DL=10"), acc(&sbs_hyps)));

        all_rows.extend([m_bs, m_sbs, m_sbs0]);
    }

    report("table3_sbs", "Table 3 — BS vs SBS wall time (retro)", &all_rows);

    println!("\n=== Table 4 — top-N accuracy, BS vs SBS (must match) ===");
    println!("config            | top-1  top-3  top-5  top-10 top-25");
    let mut tsv = String::from("config\ttop1\ttop3\ttop5\ttop10\ttop25\n");
    for (label, accs) in &table4 {
        print!("{label:<17} |");
        tsv.push_str(label);
        for a in accs {
            print!(" {a:5.1}%");
            tsv.push_str(&format!("\t{a:.2}"));
        }
        for _ in accs.len()..5 {
            tsv.push_str("\t");
        }
        println!();
        tsv.push('\n');
    }
    let _ = std::fs::create_dir_all("bench_out");
    let _ = std::fs::write("bench_out/table4_accuracy.tsv", tsv);
    Ok(())
}
