//! Table 2 reproduction: product-prediction inference wall time with
//! standard vs speculative greedy decoding.
//!
//! Paper (USPTO-MIT test, 40k reactions, H100):
//!     GREEDY (B=1)                      61.8 ± 5.9 min
//!     GREEDY SPECULATIVE (B=1, DL=4)    26.0 ± 2.1 min   (2.4x)
//!     GREEDY SPECULATIVE (B=1, DL=10)   17.1 ± 0.3 min   (3.6x)
//!     GREEDY (B=32)                      4.1 ± 0.1 min
//! plus a corpus acceptance rate of 79%.
//!
//! Here: a subset of the synthetic fwd test split on CPU PJRT — absolute
//! times differ, the *shape* (ordering and rough ratios) is the claim
//! under reproduction. RXNSPEC_LIMIT controls the subset (default 60).

use rxnspec::bench::{
    bench_json_path, eval_setup, json, json_flag, limit, measure, report, speedup, DeviceModel,
};
use rxnspec::cache::{DraftStore, ResultCache};
use rxnspec::decoding::{greedy_batch, spec_greedy_batch, spec_greedy_batch_corpus, Backend};
use rxnspec::draft::DraftConfig;
use rxnspec::testutil::ForceStateless;

/// Sum the trace-populated phase times (encode, extend, verify; µs)
/// over one batch's outputs.
fn phase_add(mut acc: [u64; 3], outs: &[rxnspec::decoding::DecodeOutput]) -> [u64; 3] {
    for o in outs {
        acc[0] += o.stats.encode_us;
        acc[1] += o.stats.extend_us;
        acc[2] += o.stats.verify_us;
    }
    acc
}

fn main() -> anyhow::Result<()> {
    let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
    let n = limit(60).min(split.len());
    let srcs: Vec<Vec<i64>> = split[..n]
        .iter()
        .map(|e| vocab.encode_wrapped(&e.src))
        .collect::<Result<_, _>>()?;
    let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();
    eprintln!("table2: {} queries, backend dims {:?}", n, backend.dims());
    // Span collection on for the whole bench: the enc/ext/ver phase
    // columns below come from the trace layer's per-thread accumulators.
    // Tracing never changes outputs — the losslessness asserts at the
    // bottom run under it.
    rxnspec::trace::set_enabled(true);
    let dm = DeviceModel::calibrate(&backend, &vocab, &split[0].src)?;
    eprintln!("device model (single-row call latency): {}", dm.describe());

    let mut rows = Vec::new();

    // GREEDY (B=1): one query at a time, KV-cached session path.
    rows.push(measure("greedy (B=1)", 0, 2, || {
        let _ = backend.take_call_log();
        let mut calls = 0usize;
        let mut toks = 0usize;
        let mut computed = 0usize;
        let mut ph = [0u64; 3];
        for s in &refs {
            let out = greedy_batch(&backend, &[s]).unwrap();
            calls += out[0].stats.decoder_calls;
            toks += out[0].hyps[0].tokens.len();
            computed += out[0].stats.tokens_computed;
            ph = phase_add(ph, &out);
        }
        let proj = dm.project(&backend.take_call_log());
        vec![
            ("calls".into(), calls as f64),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), 0.0),
            ("recomp_tok".into(), computed as f64 / toks.max(1) as f64),
            ("proj_s".into(), proj),
            ("enc_ms".into(), ph[0] as f64 / 1e3),
            ("ext_ms".into(), ph[1] as f64 / 1e3),
            ("ver_ms".into(), ph[2] as f64 / 1e3),
        ]
    }));

    // GREEDY (B=1) with the session cache suppressed — the pre-session
    // baseline. The per-step decoder FLOPs proxy ("recomp_tok": token
    // positions recomputed per emitted token) quantifies what KV caching
    // saves; outputs must not change at all.
    rows.push(measure("greedy (B=1, no-cache)", 0, 2, || {
        let nocache = ForceStateless(&backend);
        let _ = backend.take_call_log();
        let mut calls = 0usize;
        let mut toks = 0usize;
        let mut computed = 0usize;
        let mut ph = [0u64; 3];
        for s in &refs {
            let out = greedy_batch(&nocache, &[s]).unwrap();
            calls += out[0].stats.decoder_calls;
            toks += out[0].hyps[0].tokens.len();
            computed += out[0].stats.tokens_computed;
            ph = phase_add(ph, &out);
        }
        let proj = dm.project(&backend.take_call_log());
        vec![
            ("calls".into(), calls as f64),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), 0.0),
            ("recomp_tok".into(), computed as f64 / toks.max(1) as f64),
            ("proj_s".into(), proj),
            ("enc_ms".into(), ph[0] as f64 / 1e3),
            ("ext_ms".into(), ph[1] as f64 / 1e3),
            ("ver_ms".into(), ph[2] as f64 / 1e3),
        ]
    }));

    // SPECULATIVE (B=1, DL=4 / DL=10).
    for dl in [4usize, 10] {
        let cfg = DraftConfig::new(dl);
        rows.push(measure(&format!("spec (B=1, DL={dl})"), 0, 2, || {
            let _ = backend.take_call_log();
            let mut calls = 0usize;
            let mut toks = 0usize;
            let mut computed = 0usize;
            let mut ph = [0u64; 3];
            let mut acc = rxnspec::draft::Acceptance::default();
            for s in &refs {
                let out = spec_greedy_batch(&backend, &[s], &cfg).unwrap();
                calls += out[0].stats.decoder_calls;
                toks += out[0].hyps[0].tokens.len();
                computed += out[0].stats.tokens_computed;
                acc.merge(&out[0].stats.acceptance);
                ph = phase_add(ph, &out);
            }
            let proj = dm.project(&backend.take_call_log());
            vec![
                ("calls".into(), calls as f64),
                ("tokens".into(), toks as f64),
                ("acc_rate".into(), acc.rate()),
                ("recomp_tok".into(), computed as f64 / toks.max(1) as f64),
                ("proj_s".into(), proj),
                ("enc_ms".into(), ph[0] as f64 / 1e3),
                ("ext_ms".into(), ph[1] as f64 / 1e3),
                ("ver_ms".into(), ph[2] as f64 / 1e3),
            ]
        }));
    }

    // GREEDY (B=32): batched.
    rows.push(measure("greedy (B=32)", 0, 2, || {
        let _ = backend.take_call_log();
        let mut calls = 0usize;
        let mut toks = 0usize;
        let mut computed = 0usize;
        let mut ph = [0u64; 3];
        for chunk in refs.chunks(32) {
            let out = greedy_batch(&backend, chunk).unwrap();
            calls += out[0].stats.decoder_calls;
            toks += out.iter().map(|o| o.hyps[0].tokens.len()).sum::<usize>();
            computed += out[0].stats.tokens_computed;
            ph = phase_add(ph, &out);
        }
        let proj = dm.project(&backend.take_call_log());
        vec![
            ("calls".into(), calls as f64),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), 0.0),
            ("recomp_tok".into(), computed as f64 / toks.max(1) as f64),
            ("proj_s".into(), proj),
            ("enc_ms".into(), ph[0] as f64 / 1e3),
            ("ext_ms".into(), ph[1] as f64 / 1e3),
            ("ver_ms".into(), ph[2] as f64 / 1e3),
        ]
    }));

    // --- warm-vs-cold cache passes (rust/src/cache/) --------------------
    // Cold = every row above. Warm DraftStore: corpus windows mined from
    // a prior pass over the same traffic supplement the query copies
    // (outputs stay token-exact; acceptance and calls are the delta).
    let store = DraftStore::new(10, 4096);
    let rcache: ResultCache<Vec<i64>> = ResultCache::new(4096, 8);
    for s in &refs {
        let out = greedy_batch(&backend, &[s]).unwrap();
        store.record(&out[0].hyps[0].tokens);
        rcache.insert(1, s.to_vec(), out[0].hyps[0].tokens.clone());
    }
    let cfg10 = DraftConfig::new(10);
    let mut corpus_accepted = 0usize;
    let warm_idx = rows.len();
    rows.push(measure("spec (B=1, DL=10, warm store)", 0, 2, || {
        let _ = backend.take_call_log();
        let corpus = store.top_k(8);
        let mut calls = 0usize;
        let mut toks = 0usize;
        let mut computed = 0usize;
        let mut acc = rxnspec::draft::Acceptance::default();
        corpus_accepted = 0;
        let mut ph = [0u64; 3];
        for s in &refs {
            let out = spec_greedy_batch_corpus(&backend, &[s], &cfg10, &corpus).unwrap();
            calls += out[0].stats.decoder_calls;
            toks += out[0].hyps[0].tokens.len();
            computed += out[0].stats.tokens_computed;
            corpus_accepted += out[0].stats.accepted_corpus_tokens;
            acc.merge(&out[0].stats.acceptance);
            ph = phase_add(ph, &out);
        }
        let proj = dm.project(&backend.take_call_log());
        vec![
            ("calls".into(), calls as f64),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), acc.rate()),
            ("recomp_tok".into(), computed as f64 / toks.max(1) as f64),
            ("proj_s".into(), proj),
            ("enc_ms".into(), ph[0] as f64 / 1e3),
            ("ext_ms".into(), ph[1] as f64 / 1e3),
            ("ver_ms".into(), ph[2] as f64 / 1e3),
        ]
    }));

    // Warm ResultCache: repeat traffic is served without any decoding —
    // the B=1 serving ceiling for recurring queries.
    let rcache_idx = rows.len();
    rows.push(measure("greedy (B=1, result cache)", 0, 2, || {
        let mut toks = 0usize;
        for s in &refs {
            let hit = rcache.get(1, s).expect("warm result cache must hit");
            toks += hit.len();
        }
        vec![
            ("calls".into(), 0.0),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), 0.0),
            ("recomp_tok".into(), 0.0),
            ("proj_s".into(), 0.0),
            ("enc_ms".into(), 0.0),
            ("ext_ms".into(), 0.0),
            ("ver_ms".into(), 0.0),
        ]
    }));

    report("table2_greedy", "Table 2 — greedy vs speculative greedy (fwd)", &rows);
    println!(
        "\nwall speedups vs greedy B=1: DL=4 {:.2}x (paper 2.4x), DL=10 {:.2}x (paper 3.6x), \
         B=32 {:.2}x (paper 15x)",
        speedup(&rows[0], &rows[2]),
        speedup(&rows[0], &rows[3]),
        speedup(&rows[0], &rows[4]),
    );
    let aux = |r: &rxnspec::bench::Measurement, k: &str| r.aux_metric(k);
    println!(
        "parallel-device projection: greedy {:.2}s -> DL=4 {:.2}s ({:.2}x), DL=10 {:.2}s ({:.2}x)",
        aux(&rows[0], "proj_s"),
        aux(&rows[2], "proj_s"),
        aux(&rows[0], "proj_s") / aux(&rows[2], "proj_s"),
        aux(&rows[3], "proj_s"),
        aux(&rows[0], "proj_s") / aux(&rows[3], "proj_s"),
    );
    println!(
        "acceptance rate DL=10: {:.0}% (paper: 79%)",
        aux(&rows[3], "acc_rate") * 100.0
    );
    // The session-cache acceptance criterion: ≥2x fewer token positions
    // recomputed per emitted token vs the stateless baseline. Both
    // session-capable backends compute each position once — the
    // reference transformer via its KV-cached CachedSession, the PJRT
    // backend via the deccache artifacts (recomp_tok ~L/2 → ~1); only a
    // PJRT artifact set without deccache rows still reports parity here
    // (stateless-recompute fallback).
    let (cached, stateless) = (aux(&rows[0], "recomp_tok"), aux(&rows[1], "recomp_tok"));
    println!(
        "decoder FLOPs proxy (tokens recomputed per emitted token): \
         cached {cached:.2} vs stateless {stateless:.2} ({:.2}x reduction)",
        stateless / cached.max(1e-9)
    );

    let by_label = |label: &str| {
        rows.iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("missing bench row {label:?}"))
    };
    let cold10_row = by_label("spec (B=1, DL=10)");
    let greedy_row = by_label("greedy (B=1)");
    let warm_row = &rows[warm_idx];
    let rcache_row = &rows[rcache_idx];
    println!(
        "warm-vs-cold: DL=10 warm-store {:.2}x vs cold DL=10, acc {:.0}% -> {:.0}% \
         ({} corpus-accepted tokens); result-cache repeat pass {:.2}x vs greedy",
        speedup(cold10_row, warm_row),
        aux(cold10_row, "acc_rate") * 100.0,
        aux(warm_row, "acc_rate") * 100.0,
        corpus_accepted,
        speedup(greedy_row, rcache_row),
    );

    // Sanity: speculative, cache-suppressed, and warm-store outputs are
    // identical to greedy outputs; the result cache replays them verbatim.
    let head = 5.min(refs.len());
    let g = greedy_batch(&backend, &refs[..head])?;
    let s = spec_greedy_batch(&backend, &refs[..head], &DraftConfig::new(10))?;
    let nc = greedy_batch(&ForceStateless(&backend), &refs[..head])?;
    let ws = spec_greedy_batch_corpus(&backend, &refs[..head], &cfg10, &store.top_k(8))?;
    for (((a, b), c), w) in g.iter().zip(&s).zip(&nc).zip(&ws) {
        assert_eq!(a.hyps[0].tokens, b.hyps[0].tokens, "losslessness violated");
        assert_eq!(a.hyps[0].tokens, c.hyps[0].tokens, "session cache changed output");
        assert_eq!(a.hyps[0].tokens, w.hyps[0].tokens, "draft store changed output");
    }
    for (i, r) in refs[..head].iter().enumerate() {
        assert_eq!(
            rcache.get(1, r).as_deref(),
            Some(g[i].hyps[0].tokens.as_slice()),
            "result cache must replay the decoded tokens verbatim"
        );
    }
    println!(
        "losslessness check passed (greedy == speculative == no-cache == warm-store \
         == cached outputs)"
    );

    // Machine-readable perf trajectory (`--json`): tok/s + recomp_tok per
    // configuration, merged into BENCH_kernels.json next to the
    // kernel_micro section.
    if json_flag() {
        let mut entries: Vec<(String, json::Val)> = Vec::new();
        for r in &rows {
            let toks = aux(r, "tokens");
            entries.push((
                r.label.clone(),
                json::Val::obj(vec![
                    ("tok_s".into(), json::Val::num(toks / r.mean_s().max(1e-12))),
                    ("recomp_tok".into(), json::Val::num(aux(r, "recomp_tok"))),
                    ("calls".into(), json::Val::num(aux(r, "calls"))),
                ]),
            ));
        }
        entries.push((
            "speedup_dl10_vs_greedy".into(),
            json::Val::num(speedup(greedy_row, cold10_row)),
        ));
        let path = bench_json_path();
        json::merge_section(&path, "table2_greedy", json::Val::obj(entries))?;
        println!("(updated {})", path.display());
    }
    Ok(())
}
