//! Table 2 reproduction: product-prediction inference wall time with
//! standard vs speculative greedy decoding.
//!
//! Paper (USPTO-MIT test, 40k reactions, H100):
//!     GREEDY (B=1)                      61.8 ± 5.9 min
//!     GREEDY SPECULATIVE (B=1, DL=4)    26.0 ± 2.1 min   (2.4x)
//!     GREEDY SPECULATIVE (B=1, DL=10)   17.1 ± 0.3 min   (3.6x)
//!     GREEDY (B=32)                      4.1 ± 0.1 min
//! plus a corpus acceptance rate of 79%.
//!
//! Here: a subset of the synthetic fwd test split on CPU PJRT — absolute
//! times differ, the *shape* (ordering and rough ratios) is the claim
//! under reproduction. RXNSPEC_LIMIT controls the subset (default 60).

use rxnspec::bench::{eval_setup, limit, measure, report, speedup, DeviceModel};
use rxnspec::decoding::{greedy_batch, spec_greedy_batch, Backend};
use rxnspec::draft::DraftConfig;

fn main() -> anyhow::Result<()> {
    let (vocab, backend, split) = eval_setup("fwd")?;
    backend.precompile()?;
    let n = limit(60).min(split.len());
    let srcs: Vec<Vec<i64>> = split[..n]
        .iter()
        .map(|e| vocab.encode_wrapped(&e.src))
        .collect::<Result<_, _>>()?;
    let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();
    eprintln!("table2: {} queries, backend dims {:?}", n, backend.dims());
    let dm = DeviceModel::calibrate(&backend, &vocab, &split[0].src)?;
    eprintln!("device model (single-row call latency): {}", dm.describe());

    let mut rows = Vec::new();

    // GREEDY (B=1): one query at a time.
    rows.push(measure("greedy (B=1)", 0, 2, || {
        let _ = backend.take_call_log();
        let mut calls = 0usize;
        let mut toks = 0usize;
        for s in &refs {
            let out = greedy_batch(&backend, &[s]).unwrap();
            calls += out[0].stats.decoder_calls;
            toks += out[0].hyps[0].tokens.len();
        }
        let proj = dm.project(&backend.take_call_log());
        vec![
            ("calls".into(), calls as f64),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), 0.0),
            ("proj_s".into(), proj),
        ]
    }));

    // SPECULATIVE (B=1, DL=4 / DL=10).
    for dl in [4usize, 10] {
        let cfg = DraftConfig::new(dl);
        rows.push(measure(&format!("spec (B=1, DL={dl})"), 0, 2, || {
            let _ = backend.take_call_log();
            let mut calls = 0usize;
            let mut toks = 0usize;
            let mut acc = rxnspec::draft::Acceptance::default();
            for s in &refs {
                let out = spec_greedy_batch(&backend, &[s], &cfg).unwrap();
                calls += out[0].stats.decoder_calls;
                toks += out[0].hyps[0].tokens.len();
                acc.merge(&out[0].stats.acceptance);
            }
            let proj = dm.project(&backend.take_call_log());
            vec![
                ("calls".into(), calls as f64),
                ("tokens".into(), toks as f64),
                ("acc_rate".into(), acc.rate()),
                ("proj_s".into(), proj),
            ]
        }));
    }

    // GREEDY (B=32): batched.
    rows.push(measure("greedy (B=32)", 0, 2, || {
        let _ = backend.take_call_log();
        let mut calls = 0usize;
        let mut toks = 0usize;
        for chunk in refs.chunks(32) {
            let out = greedy_batch(&backend, chunk).unwrap();
            calls += out[0].stats.decoder_calls;
            toks += out.iter().map(|o| o.hyps[0].tokens.len()).sum::<usize>();
        }
        let proj = dm.project(&backend.take_call_log());
        vec![
            ("calls".into(), calls as f64),
            ("tokens".into(), toks as f64),
            ("acc_rate".into(), 0.0),
            ("proj_s".into(), proj),
        ]
    }));

    report("table2_greedy", "Table 2 — greedy vs speculative greedy (fwd)", &rows);
    println!(
        "\nwall speedups vs greedy B=1: DL=4 {:.2}x (paper 2.4x), DL=10 {:.2}x (paper 3.6x), \
         B=32 {:.2}x (paper 15x)",
        speedup(&rows[0], &rows[1]),
        speedup(&rows[0], &rows[2]),
        speedup(&rows[0], &rows[3]),
    );
    let proj = |r: &rxnspec::bench::Measurement| {
        r.aux.iter().find(|a| a.0 == "proj_s").map(|a| a.1).unwrap_or(0.0)
    };
    println!(
        "parallel-device projection: greedy {:.2}s -> DL=4 {:.2}s ({:.2}x), DL=10 {:.2}s ({:.2}x)",
        proj(&rows[0]),
        proj(&rows[1]),
        proj(&rows[0]) / proj(&rows[1]),
        proj(&rows[2]),
        proj(&rows[0]) / proj(&rows[2]),
    );
    println!(
        "acceptance rate DL=10: {:.0}% (paper: 79%)",
        rows[2].aux.iter().find(|a| a.0 == "acc_rate").unwrap().1 * 100.0
    );

    // Sanity: speculative outputs are identical to greedy outputs.
    let g = greedy_batch(&backend, &refs[..5.min(refs.len())])?;
    let s = spec_greedy_batch(&backend, &refs[..5.min(refs.len())], &DraftConfig::new(10))?;
    for (a, b) in g.iter().zip(&s) {
        assert_eq!(a.hyps[0].tokens, b.hyps[0].tokens, "losslessness violated");
    }
    println!("losslessness check passed (greedy == speculative outputs)");
    Ok(())
}
